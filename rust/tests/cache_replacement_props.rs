//! Heterogeneous-replacement property tests for `SliceCache` (testkit
//! substrate): under random churn,
//!
//! * every evictable LSB slice leaves before ANY MSB slice is touched
//!   (the paper's §4.1 class-priority rule);
//! * pinned entries never evict;
//! * byte accounting stays exact (an independent model of the resident
//!   set agrees with `used_bytes` after every operation).

use std::collections::HashMap;

use slicemoe::cache::{Ensure, SliceCache};
use slicemoe::model::descriptor::{Plane, SliceKey};
use slicemoe::util::testkit::check;

#[derive(Clone, Debug)]
enum Op {
    Lookup(SliceKey),
    Ensure(SliceKey, u64),
    Remove(SliceKey),
    Pin(SliceKey, bool),
}

fn gen_ops(rng: &mut slicemoe::util::rng::Rng, n: usize) -> Vec<Op> {
    (0..n)
        .map(|_| {
            let layer = rng.below(4);
            let expert = rng.below(8);
            let key = if rng.bool(0.5) {
                SliceKey::msb(layer, expert)
            } else {
                SliceKey::lsb(layer, expert)
            };
            match rng.below(8) {
                0 | 1 => Op::Lookup(key),
                2..=4 => Op::Ensure(key, 5 + rng.below(40) as u64),
                5 => Op::Remove(key),
                6 => Op::Pin(key, true),
                _ => Op::Pin(key, false),
            }
        })
        .collect()
}

#[test]
fn lsb_class_always_evicts_before_any_msb() {
    check(
        "lsb-before-msb",
        200,
        0x15B,
        |rng| {
            let cap = 60 + rng.below(300) as u64;
            (cap, gen_ops(rng, 250))
        },
        |(cap, ops)| {
            let mut c = SliceCache::new(*cap);
            for op in ops {
                if let Op::Ensure(key, bytes) = op {
                    if *bytes > *cap {
                        continue;
                    }
                    if let Ensure::Inserted { evicted } = c.ensure(*key, *bytes) {
                        // within one eviction batch, every LSB precedes
                        // every MSB (class priority, LRU within class)
                        let first_msb = evicted.iter().position(|k| k.plane == Plane::Msb);
                        if let Some(i) = first_msb {
                            if evicted[i..].iter().any(|k| k.plane == Plane::Lsb) {
                                return Err(format!(
                                    "LSB evicted after an MSB in batch {evicted:?}"
                                ));
                            }
                            // an MSB fell: no unpinned LSB may survive
                            // (the inserted key itself is exempt)
                            for k in c.keys_mru() {
                                if k.plane == Plane::Lsb && k != *key && !c.is_pinned(k) {
                                    return Err(format!(
                                        "MSB evicted while unpinned LSB {k:?} resident"
                                    ));
                                }
                            }
                        }
                    }
                } else {
                    apply_simple(&mut c, op);
                }
                c.check_invariants()?;
            }
            Ok(())
        },
    );
}

#[test]
fn pinned_entries_never_evict_under_churn() {
    check(
        "pinned-survive",
        200,
        0x919,
        |rng| {
            let cap = 80 + rng.below(200) as u64;
            // a few entries that will be pinned up-front, then churn
            let pinned: Vec<(SliceKey, u64)> = (0..2 + rng.below(3))
                .map(|i| {
                    let key = if i % 2 == 0 {
                        SliceKey::msb(i, i)
                    } else {
                        SliceKey::lsb(i, i)
                    };
                    (key, 5 + rng.below(15) as u64)
                })
                .collect();
            (cap, pinned, gen_ops(rng, 250))
        },
        |(cap, pinned, ops)| {
            let mut c = SliceCache::new(*cap);
            for &(key, bytes) in pinned {
                let _ = c.ensure(key, bytes);
                c.pin(key, true);
            }
            let protected: Vec<SliceKey> = pinned.iter().map(|&(k, _)| k).collect();
            for op in ops {
                match op {
                    // churn must not unpin or remove the protected set
                    Op::Pin(k, _) | Op::Remove(k) if protected.contains(k) => continue,
                    Op::Ensure(key, bytes) => {
                        if *bytes <= *cap && !protected.contains(key) {
                            let _ = c.ensure(*key, *bytes);
                        }
                    }
                    other => apply_simple(&mut c, other),
                }
                for k in &protected {
                    if !c.contains(*k) {
                        return Err(format!("pinned {k:?} was evicted"));
                    }
                }
                c.check_invariants()?;
            }
            Ok(())
        },
    );
}

#[test]
fn byte_accounting_is_exact_under_random_churn() {
    check(
        "byte-accounting",
        250,
        0xB17E,
        |rng| {
            let cap = 50 + rng.below(400) as u64;
            (cap, gen_ops(rng, 300))
        },
        |(cap, ops)| {
            let mut c = SliceCache::new(*cap);
            // independent model of the resident set
            let mut model: HashMap<SliceKey, u64> = HashMap::new();
            for op in ops {
                match op {
                    Op::Lookup(k) => {
                        let hit = c.lookup(*k);
                        if hit != model.contains_key(k) {
                            return Err(format!("hit/miss mismatch on {k:?}"));
                        }
                    }
                    Op::Ensure(key, bytes) => {
                        if *bytes > *cap {
                            continue;
                        }
                        match c.ensure(*key, *bytes) {
                            Ensure::Hit => {
                                if !model.contains_key(key) {
                                    return Err(format!("spurious hit {key:?}"));
                                }
                            }
                            Ensure::Inserted { evicted } => {
                                for e in &evicted {
                                    if model.remove(e).is_none() {
                                        return Err(format!("evicted absent {e:?}"));
                                    }
                                }
                                model.insert(*key, *bytes);
                            }
                            Ensure::TooLarge => {
                                // pinned entries can block; the insert must
                                // NOT have happened
                                if c.contains(*key) && !model.contains_key(key) {
                                    return Err("TooLarge but resident".into());
                                }
                                // evictions may still have occurred; resync
                                model.retain(|k, _| c.contains(*k));
                            }
                        }
                    }
                    Op::Remove(k) => {
                        let removed = c.remove(*k);
                        if removed != model.remove(k).is_some() {
                            return Err(format!("remove mismatch on {k:?}"));
                        }
                    }
                    Op::Pin(k, p) => {
                        let _ = c.pin(*k, *p);
                    }
                }
                let expect: u64 = model.values().sum();
                if c.used_bytes() != expect {
                    return Err(format!(
                        "byte accounting drifted: cache {} vs model {}",
                        c.used_bytes(),
                        expect
                    ));
                }
                if c.len() != model.len() {
                    return Err(format!("len {} vs model {}", c.len(), model.len()));
                }
                if c.used_bytes() > *cap {
                    return Err("over capacity".into());
                }
                c.check_invariants()?;
            }
            Ok(())
        },
    );
}

fn apply_simple(c: &mut SliceCache, op: &Op) {
    match op {
        Op::Lookup(k) => {
            c.lookup(*k);
        }
        Op::Remove(k) => {
            c.remove(*k);
        }
        Op::Pin(k, p) => {
            c.pin(*k, *p);
        }
        Op::Ensure(..) => unreachable!("handled by callers"),
    }
}
