//! Flight-recorder parity + attribution reconciliation.
//!
//! Two contracts anchor the telemetry subsystem:
//!
//! * **observation-only**: a `ServeLoop`/`WaveEngine` run produces the
//!   bit-identical op sequence — token counts, expert counters, miss/hit
//!   statistics, simulated energies, cache stats — with the recorder
//!   disabled, enabled, or enabled on a ring so small every event after
//!   the first handful is dropped. No hook returns a value the pipeline
//!   consumes, so "approximately equal" would already be a bug;
//! * **exact reconciliation**: the attribution table's run-level totals
//!   EQUAL the pipeline's own aggregates — flash bytes/fetches and the
//!   six per-phase component joules against `Ledger` (the recorder
//!   recomputes each charge from identical inputs in identical order, so
//!   the f64 sums match to the last bit), plane hit/miss/eviction counts
//!   against `CacheStats` deltas. Reconciliation must survive ring
//!   saturation: the ring drops events, the tables drop nothing.

use std::sync::Arc;

use slicemoe::cache::{ShardedSliceCache, WarmupStrategy};
use slicemoe::model::ModelDesc;
use slicemoe::serve::{CostModelBackend, ServeConfig, ServeLoop, WaveEngine};
use slicemoe::sim::TraceParams;
use slicemoe::telemetry::{Clock, Recorder, TelemetryHub};

const PREFILL_TOKENS: usize = 32;
const DECODE_TOKENS: usize = 24;

fn tiny_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::gsm8k_default(ModelDesc::tiny());
    cfg.cache_bytes = cfg.unit_bytes() * 8;
    cfg
}

fn sharded(cfg: &ServeConfig, shards: usize) -> Arc<ShardedSliceCache> {
    let mut c = ShardedSliceCache::new(cfg.cache_bytes, shards);
    c.set_heterogeneous(cfg.heterogeneous_lsb);
    Arc::new(c)
}

/// One full request (32 prefill + 24 decode tokens) on a fresh sharded
/// cache, with `recorder` riding inside the loop.
fn run_loop(
    cfg: &ServeConfig,
    shards: usize,
    recorder: Recorder,
) -> (ServeLoop, Arc<ShardedSliceCache>) {
    let cache = sharded(cfg, shards);
    let mut lp = ServeLoop::with_sharded_cache(cfg.clone(), Arc::clone(&cache));
    lp.recorder = recorder;
    let mut be = CostModelBackend::new(&cfg.desc, TraceParams::default(), PREFILL_TOKENS, cfg.seed);
    lp.prefill(&mut be, PREFILL_TOKENS).unwrap();
    for _ in 0..DECODE_TOKENS {
        lp.decode_token(&mut be).unwrap();
    }
    (lp, cache)
}

/// The full bit-exact comparison list `wave_decode_parity` pins for the
/// batch-of-one reduction, reused here for the recorder on/off axis.
fn assert_loops_bit_exact(a: &mut ServeLoop, b: &mut ServeLoop, ctx: &str) {
    assert_eq!(a.ledger.decode_steps, b.ledger.decode_steps, "{ctx}");
    assert_eq!(a.prefill_tokens, b.prefill_tokens, "{ctx}");
    assert_eq!(a.counters.n_high, b.counters.n_high, "{ctx}");
    assert_eq!(a.counters.n_low, b.counters.n_low, "{ctx}");
    assert_eq!(a.counters.n_dropped, b.counters.n_dropped, "{ctx}");
    assert_eq!(a.counters.n_substituted, b.counters.n_substituted, "{ctx}");
    assert_eq!(a.counters.n_degraded, b.counters.n_degraded, "{ctx}");
    assert_eq!(a.counters.n_critical, b.counters.n_critical, "{ctx}");
    assert_eq!(a.steady_accesses, b.steady_accesses, "{ctx}");
    assert_eq!(a.steady_flash, b.steady_flash, "{ctx}");
    assert_eq!(a.decode_flash_fetches, b.decode_flash_fetches, "{ctx}");
    assert_eq!(a.miss_rate(), b.miss_rate(), "{ctx}");
    assert_eq!(a.ledger.decode_energy_j(), b.ledger.decode_energy_j(), "{ctx}");
    assert_eq!(a.ledger.prefill_energy_j(), b.ledger.prefill_energy_j(), "{ctx}");
    assert_eq!(a.ledger.flash_bytes, b.ledger.flash_bytes, "{ctx}");
    assert_eq!(a.ledger.flash_fetches, b.ledger.flash_fetches, "{ctx}");
    assert_eq!(a.hit_rates(), b.hit_rates(), "{ctx}");
}

#[test]
fn serve_loop_is_bit_exact_with_telemetry_off_on_and_saturated() {
    for shards in [1usize, 4] {
        for constraint in [f64::INFINITY, 0.05] {
            let ctx = format!("shards {shards}, constraint {constraint}");
            let mut cfg = tiny_cfg();
            cfg.constraint = constraint;

            let (mut off, off_cache) = run_loop(&cfg, shards, Recorder::disabled());
            let (clock, _hand) = Clock::manual();
            let (mut on, on_cache) =
                run_loop(&cfg, shards, Recorder::enabled(1, clock.clone(), 65_536, 0.1));
            // an 8-slot ring saturates within the first prefill layer
            let (mut sat, sat_cache) =
                run_loop(&cfg, shards, Recorder::enabled(2, clock, 8, 0.1));

            assert_loops_bit_exact(&mut off, &mut on, &ctx);
            assert_loops_bit_exact(&mut off, &mut sat, &ctx);
            assert_eq!(off_cache.stats(), on_cache.stats(), "{ctx}");
            assert_eq!(off_cache.stats(), sat_cache.stats(), "{ctx}");
            on_cache.check_invariants().unwrap();
            sat_cache.check_invariants().unwrap();

            // the healthy ring dropped nothing; the tiny ring dropped
            // events (counted, never reallocated) yet observed the same run
            assert_eq!(on.recorder.dropped_events(), 0, "{ctx}");
            assert!(sat.recorder.dropped_events() > 0, "{ctx}");
            assert!(sat.recorder.ring().len() <= 8, "{ctx}");

            // attribution is table-kept, not ring-kept: saturation loses
            // events but NO attribution — both recorders reconcile with
            // their own (identical) ledgers
            for lp in [&on, &sat] {
                assert_eq!(lp.recorder.attrib.flash_bytes, lp.ledger.flash_bytes, "{ctx}");
                assert_eq!(lp.recorder.attrib.flash_fetches, lp.ledger.flash_fetches, "{ctx}");
                assert_eq!(lp.recorder.attrib.tokens, lp.ledger.decode_steps, "{ctx}");
            }
        }
    }
}

#[test]
fn wave_engine_is_bit_exact_with_telemetry_attached() {
    for shards in [1usize, 4] {
        let ctx = format!("shards {shards}");
        let cfg = tiny_cfg();

        // reference: two requests waved with no telemetry
        let ref_cache = sharded(&cfg, shards);
        let mut eng = WaveEngine::new(Arc::clone(&ref_cache), 2);
        for id in 0..2u64 {
            let be =
                CostModelBackend::new(&cfg.desc, TraceParams::default(), PREFILL_TOKENS, cfg.seed + id);
            eng.admit(id, cfg.clone(), be, PREFILL_TOKENS, DECODE_TOKENS).unwrap();
        }
        let mut reference = Vec::new();
        while !eng.is_idle() {
            reference.extend(eng.step_wave().unwrap());
        }
        reference.sort_by_key(|d| d.id);

        // identical wave with a hub attached (manual clock: deterministic)
        let (clock, _hand) = Clock::manual();
        let hub = Arc::new(TelemetryHub::new(clock));
        let cache = sharded(&cfg, shards);
        let mut eng =
            WaveEngine::new(Arc::clone(&cache), 2).with_telemetry(Arc::clone(&hub));
        for id in 0..2u64 {
            let be =
                CostModelBackend::new(&cfg.desc, TraceParams::default(), PREFILL_TOKENS, cfg.seed + id);
            eng.admit(id, cfg.clone(), be, PREFILL_TOKENS, DECODE_TOKENS).unwrap();
        }
        let mut done = Vec::new();
        while !eng.is_idle() {
            done.extend(eng.step_wave().unwrap());
        }
        done.sort_by_key(|d| d.id);

        assert_eq!(reference.len(), 2, "{ctx}");
        assert_eq!(done.len(), 2, "{ctx}");
        for (r, t) in reference.iter_mut().zip(&mut done) {
            assert_eq!(r.id, t.id, "{ctx}");
            assert_eq!(r.decode_tokens, t.decode_tokens, "{ctx}");
            assert!(t.lane.recorder.is_enabled(), "{ctx}: hub plants recorders");
            assert_loops_bit_exact(&mut r.lane, &mut t.lane, &ctx);
        }
        assert_eq!(ref_cache.stats(), cache.stats(), "{ctx}");
        cache.check_invariants().unwrap();

        // absorbing both lanes gives hub totals that reconcile with the
        // SUM of the per-request ledgers (cross-request aggregation)
        let mut flash_bytes = 0u64;
        let mut tokens = 0u64;
        for d in &mut done {
            flash_bytes += d.lane.ledger.flash_bytes;
            tokens += d.lane.ledger.decode_steps;
            hub.absorb(std::mem::take(&mut d.lane.recorder));
        }
        let snap = hub.snapshot();
        assert_eq!(snap.absorbed_requests, 2, "{ctx}");
        assert_eq!(snap.dropped_events, 0, "{ctx}");
        assert_eq!(snap.attrib.flash_bytes, flash_bytes, "{ctx}");
        assert_eq!(snap.attrib.tokens, tokens, "{ctx}");
        assert!(!snap.events.is_empty(), "{ctx}");
    }
}

#[test]
fn attribution_reconciles_with_ledger_and_cache_stats() {
    // Pcw's reshape re-admits planned slices via `ensure` (insertions the
    // walk never sees), so the insertions reconciliation is Empty-only;
    // everything else must hold under both. Random/LastLayer evict via
    // `remove` — outside the walk, hence outside the contract.
    for (warmup, check_insertions) in
        [(WarmupStrategy::Pcw, false), (WarmupStrategy::Empty, true)]
    {
        for shards in [1usize, 4] {
            let ctx = format!("warmup {warmup:?}, shards {shards}");
            let mut cfg = tiny_cfg();
            cfg.warmup = warmup;

            let (clock, hand) = Clock::manual();
            let hub = Arc::new(TelemetryHub::new(clock));
            let cache = sharded(&cfg, shards);
            let mut lp = ServeLoop::with_sharded_cache(cfg.clone(), Arc::clone(&cache));
            lp.recorder = hub.recorder(9);
            let mut be =
                CostModelBackend::new(&cfg.desc, TraceParams::default(), PREFILL_TOKENS, cfg.seed);
            lp.prefill(&mut be, PREFILL_TOKENS).unwrap();
            for _ in 0..DECODE_TOKENS {
                hand.advance_us(1_000);
                lp.decode_token(&mut be).unwrap();
            }

            let a = &lp.recorder.attrib;

            // -- Ledger: flash traffic, token count, per-phase energies.
            // EXACT equality: same inputs, same arithmetic, same order.
            assert_eq!(a.flash_bytes, lp.ledger.flash_bytes, "{ctx}");
            assert_eq!(a.flash_fetches, lp.ledger.flash_fetches, "{ctx}");
            assert_eq!(a.tokens, lp.ledger.decode_steps, "{ctx}");
            assert_eq!(a.prefill_compute_j, lp.ledger.prefill_compute.joules, "{ctx}");
            assert_eq!(a.prefill_dram_j, lp.ledger.prefill_dram.joules, "{ctx}");
            assert_eq!(a.prefill_flash_j, lp.ledger.prefill_flash.joules, "{ctx}");
            assert_eq!(a.decode_compute_j, lp.ledger.decode_compute.joules, "{ctx}");
            assert_eq!(a.decode_dram_j, lp.ledger.decode_dram.joules, "{ctx}");
            assert_eq!(a.decode_flash_j, lp.ledger.decode_flash.joules, "{ctx}");
            // (whole-run energy reconciles too, but only component-wise:
            // summing six f64s in a different association order than the
            // ledger's phase subtotals would not be bit-identical)

            // -- CacheStats: the walk observes every lookup/fill/eviction
            // the cache counted (fresh cache, so totals ARE the deltas)
            let s = cache.stats();
            assert_eq!(a.msb_hits, s.msb_hits, "{ctx}");
            assert_eq!(a.msb_misses, s.msb_misses, "{ctx}");
            assert_eq!(a.lsb_hits, s.lsb_hits, "{ctx}");
            assert_eq!(a.lsb_misses, s.lsb_misses, "{ctx}");
            assert_eq!(a.evictions, s.evictions, "{ctx}");
            if check_insertions {
                assert_eq!(a.flash_fetches, s.insertions, "{ctx}");
            }

            // -- per-expert rows sum back to the table-level totals
            let row_bytes: u64 = a.iter().map(|(_, r)| r.fetched_bytes).sum();
            let row_fetches: u64 = a.iter().map(|(_, r)| r.fetches).sum();
            let row_evictions: u64 = a.iter().map(|(_, r)| r.evictions).sum();
            assert_eq!(row_bytes, a.flash_bytes, "{ctx}");
            assert_eq!(row_fetches, a.flash_fetches, "{ctx}");
            assert_eq!(row_evictions, a.evictions, "{ctx}");
            assert!(a.n_rows() > 0, "{ctx}");

            // -- the run actually exercised the interesting paths
            assert!(a.flash_fetches > 0, "{ctx}");
            assert!(a.evictions > 0, "{ctx}: 8-unit cache must evict");

            // -- hub absorption preserves every total bit-exactly
            let (fb, ff, tok, ev, energy) = (
                a.flash_bytes,
                a.flash_fetches,
                a.tokens,
                a.evictions,
                a.total_energy_j(),
            );
            hub.absorb(std::mem::take(&mut lp.recorder));
            let snap = hub.snapshot();
            assert_eq!(snap.absorbed_requests, 1, "{ctx}");
            assert_eq!(snap.dropped_events, 0, "{ctx}");
            assert_eq!(snap.attrib.flash_bytes, fb, "{ctx}");
            assert_eq!(snap.attrib.flash_fetches, ff, "{ctx}");
            assert_eq!(snap.attrib.tokens, tok, "{ctx}");
            assert_eq!(snap.attrib.evictions, ev, "{ctx}");
            assert_eq!(snap.attrib.total_energy_j(), energy, "{ctx}");

            // the binned series conserves the same token/byte totals
            let bin_tokens: u64 = snap.bins.iter().map(|(_, b)| b.tokens).sum();
            let bin_fetch_bytes: u64 = snap.bins.iter().map(|(_, b)| b.fetch_bytes).sum();
            assert_eq!(bin_tokens, tok, "{ctx}");
            assert_eq!(bin_fetch_bytes, fb, "{ctx}");
        }
    }
}
