//! Cross-language golden test: the Rust AMAT implementation must agree
//! bit-for-bit with the python quantizer that authored the golden blob
//! (`aot.py::golden_quant_tensors` over a REAL trained expert weight).

use std::path::Path;

use slicemoe::model::blob::Blob;
use slicemoe::quant;

fn golden() -> Option<Blob> {
    let p = Path::new("artifacts/golden_quant.bin");
    if !p.exists() {
        eprintln!("golden_quant.bin missing — run `make artifacts`; skipping");
        return None;
    }
    Some(Blob::load(p).expect("parse golden blob"))
}

fn dims(b: &Blob) -> (usize, usize) {
    let s = b.get("src").unwrap().shape();
    (s[0], s[1])
}

#[test]
fn asym_codes_match_python_exactly() {
    let Some(b) = golden() else { return };
    let (r, c) = dims(&b);
    let src = b.f32("src").unwrap();
    for (bh, bl) in [(4u32, 2u32), (6, 3), (8, 4)] {
        let tag = format!("mat{bh}{bl}");
        let t = quant::quantize_asym(src, r, c, bh, 32);
        assert_eq!(t.q, b.i32(&format!("{tag}.q")).unwrap(), "{tag} codes");
        assert_eq!(t.zp, b.i32(&format!("{tag}.zp")).unwrap(), "{tag} zp");
        let py_scale = b.f32(&format!("{tag}.scale")).unwrap();
        for (i, (a, p)) in t.scale.iter().zip(py_scale).enumerate() {
            assert!((a - p).abs() <= 1e-6 * p.abs().max(1e-12), "{tag} scale[{i}]: {a} vs {p}");
        }
    }
}

#[test]
fn planes_and_amat_match_python() {
    let Some(b) = golden() else { return };
    let (r, c) = dims(&b);
    let src = b.f32("src").unwrap();
    for (bh, bl) in [(4u32, 2u32), (6, 3), (8, 4)] {
        let tag = format!("mat{bh}{bl}");
        let t = quant::quantize_asym(src, r, c, bh, 32);
        let (msb, lsb) = quant::split_planes(&t, bl);
        assert_eq!(msb, b.i32(&format!("{tag}.msb")).unwrap(), "{tag} msb");
        assert_eq!(lsb, b.i32(&format!("{tag}.lsb")).unwrap(), "{tag} lsb");
        let am = quant::truncate_amat(&t, bl);
        assert_eq!(am.zp, b.i32(&format!("{tag}.amat_zp")).unwrap(), "{tag} amat zp");
        // packed byte stream identical
        let packed = quant::pack_bits(&msb, bl);
        assert_eq!(
            packed.as_slice(),
            b.get(&format!("{tag}.packed_msb")).unwrap().as_u8().unwrap(),
            "{tag} packed msb"
        );
    }
}

#[test]
fn sym_codes_match_python() {
    let Some(b) = golden() else { return };
    let (r, c) = dims(&b);
    let src = b.f32("src").unwrap();
    for (bh, bl) in [(4u32, 2u32), (6, 3), (8, 4)] {
        let tag = format!("mat{bh}{bl}");
        let t = quant::quantize_sym(src, r, c, bh, 32);
        assert_eq!(t.q, b.i32(&format!("{tag}.sym_q")).unwrap(), "{tag} sym codes");
        let tt = quant::truncate_sym(&t, bl);
        assert_eq!(tt.q, b.i32(&format!("{tag}.symt_q")).unwrap(), "{tag} sym trunc");
    }
}

#[test]
fn dequant_matches_python() {
    let Some(b) = golden() else { return };
    let (r, c) = dims(&b);
    let src = b.f32("src").unwrap();
    for (bh, bl) in [(4u32, 2u32), (8, 4)] {
        let tag = format!("mat{bh}{bl}");
        let t = quant::quantize_asym(src, r, c, bh, 32);
        let dq = quant::dequantize(&t);
        let py = b.f32(&format!("{tag}.dequant")).unwrap();
        for (i, (a, p)) in dq.iter().zip(py).enumerate() {
            assert!((a - p).abs() <= 1e-5, "{tag} dequant[{i}]: {a} vs {p}");
        }
        let lo = quant::truncate_amat(&t, bl);
        let dql = quant::dequantize(&lo);
        let pyl = b.f32(&format!("{tag}.dequant_low")).unwrap();
        for (i, (a, p)) in dql.iter().zip(pyl).enumerate() {
            assert!((a - p).abs() <= 1e-5, "{tag} dequant_low[{i}]: {a} vs {p}");
        }
    }
}
