//! Fault-injection parity + chaos accounting (mirrors
//! `telemetry_parity.rs` for the fault subsystem).
//!
//! Three contracts anchor the fault layer:
//!
//! * **off-by-default bit-exactness**: a run with `cfg.fault = None`,
//!   `Some(FaultPlan::disabled())`, or any inert plan (zero rates)
//!   produces the bit-identical op sequence — counters, miss rates,
//!   energies, cache stats — in BOTH decode modes at shards {1, 4};
//! * **deterministic replay**: the injector is a pure hash of
//!   (plan seed, request seed, layer, expert, plane, window, attempt) —
//!   the same seeded plan replayed twice yields identical fault
//!   counters and identical ledgers, and the same request served
//!   lane-mode or waved hits the same fault sites (the wave passes the
//!   per-request token index as the fault step);
//! * **graceful degradation**: under an aggressive seeded plan every
//!   token is still served — persistent failures land in the AMAT
//!   degrade / substitute / drop arms, never in an error.

use std::sync::Arc;

use slicemoe::cache::ShardedSliceCache;
use slicemoe::fault::FaultPlan;
use slicemoe::model::ModelDesc;
use slicemoe::serve::{CostModelBackend, ServeConfig, ServeLoop, WaveEngine};
use slicemoe::sim::TraceParams;

const PREFILL_TOKENS: usize = 32;
const DECODE_TOKENS: usize = 24;

fn tiny_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::gsm8k_default(ModelDesc::tiny());
    cfg.cache_bytes = cfg.unit_bytes() * 8;
    cfg
}

fn sharded(cfg: &ServeConfig, shards: usize) -> Arc<ShardedSliceCache> {
    let mut c = ShardedSliceCache::new(cfg.cache_bytes, shards);
    c.set_heterogeneous(cfg.heterogeneous_lsb);
    Arc::new(c)
}

/// One full request on a fresh sharded cache with the given fault plan.
fn run_loop(
    cfg: &ServeConfig,
    shards: usize,
    fault: Option<FaultPlan>,
) -> (ServeLoop, Arc<ShardedSliceCache>) {
    let mut cfg = cfg.clone();
    cfg.fault = fault;
    let cache = sharded(&cfg, shards);
    let mut lp = ServeLoop::with_sharded_cache(cfg.clone(), Arc::clone(&cache));
    let mut be = CostModelBackend::new(&cfg.desc, TraceParams::default(), PREFILL_TOKENS, cfg.seed);
    lp.prefill(&mut be, PREFILL_TOKENS).unwrap();
    for _ in 0..DECODE_TOKENS {
        lp.decode_token(&mut be).unwrap();
    }
    (lp, cache)
}

/// The same bit-exact comparison list `telemetry_parity.rs` pins.
fn assert_loops_bit_exact(a: &ServeLoop, b: &ServeLoop, ctx: &str) {
    assert_eq!(a.ledger.decode_steps, b.ledger.decode_steps, "{ctx}");
    assert_eq!(a.prefill_tokens, b.prefill_tokens, "{ctx}");
    assert_eq!(a.counters.n_high, b.counters.n_high, "{ctx}");
    assert_eq!(a.counters.n_low, b.counters.n_low, "{ctx}");
    assert_eq!(a.counters.n_dropped, b.counters.n_dropped, "{ctx}");
    assert_eq!(a.counters.n_substituted, b.counters.n_substituted, "{ctx}");
    assert_eq!(a.counters.n_degraded, b.counters.n_degraded, "{ctx}");
    assert_eq!(a.counters.n_critical, b.counters.n_critical, "{ctx}");
    assert_eq!(a.steady_accesses, b.steady_accesses, "{ctx}");
    assert_eq!(a.steady_flash, b.steady_flash, "{ctx}");
    assert_eq!(a.decode_flash_fetches, b.decode_flash_fetches, "{ctx}");
    assert_eq!(a.miss_rate(), b.miss_rate(), "{ctx}");
    assert_eq!(a.ledger.decode_energy_j(), b.ledger.decode_energy_j(), "{ctx}");
    assert_eq!(a.ledger.prefill_energy_j(), b.ledger.prefill_energy_j(), "{ctx}");
    assert_eq!(a.ledger.flash_bytes, b.ledger.flash_bytes, "{ctx}");
    assert_eq!(a.ledger.flash_fetches, b.ledger.flash_fetches, "{ctx}");
    assert_eq!(a.hit_rates(), b.hit_rates(), "{ctx}");
}

#[test]
fn serve_loop_is_bit_exact_with_faults_off_disabled_and_inert() {
    // an inert plan: nonzero seed, every rate zeroed — must not even
    // construct an injector
    let inert = FaultPlan { seed: 77, ..FaultPlan::disabled() };
    assert!(!inert.is_active());
    for shards in [1usize, 4] {
        for constraint in [f64::INFINITY, 0.05] {
            let ctx = format!("shards {shards}, constraint {constraint}");
            let mut cfg = tiny_cfg();
            cfg.constraint = constraint;

            let (none, none_cache) = run_loop(&cfg, shards, None);
            let (off, off_cache) = run_loop(&cfg, shards, Some(FaultPlan::disabled()));
            let (inrt, inert_cache) = run_loop(&cfg, shards, Some(inert));

            assert_loops_bit_exact(&none, &off, &ctx);
            assert_loops_bit_exact(&none, &inrt, &ctx);
            assert_eq!(none_cache.stats(), off_cache.stats(), "{ctx}");
            assert_eq!(none_cache.stats(), inert_cache.stats(), "{ctx}");
            off_cache.check_invariants().unwrap();
            inert_cache.check_invariants().unwrap();

            for lp in [&none, &off, &inrt] {
                assert!(!lp.fault_counters.any(), "{ctx}: no faults without a plan");
                assert_eq!(lp.fault_counters.retry_energy_j, 0.0, "{ctx}");
            }
        }
    }
}

#[test]
fn wave_engine_is_bit_exact_with_faults_disabled() {
    for shards in [1usize, 4] {
        let ctx = format!("shards {shards}");
        let run_wave = |fault: Option<FaultPlan>| {
            let mut cfg = tiny_cfg();
            cfg.fault = fault;
            let cache = sharded(&cfg, shards);
            let mut eng = WaveEngine::new(Arc::clone(&cache), 2);
            for id in 0..2u64 {
                let mut rcfg = cfg.clone();
                rcfg.seed = cfg.seed + id;
                let be = CostModelBackend::new(
                    &rcfg.desc,
                    TraceParams::default(),
                    PREFILL_TOKENS,
                    rcfg.seed,
                );
                eng.admit(id, rcfg, be, PREFILL_TOKENS, DECODE_TOKENS).unwrap();
            }
            let mut done = Vec::new();
            while !eng.is_idle() {
                done.extend(eng.step_wave().unwrap());
            }
            done.sort_by_key(|d| d.id);
            (done, cache)
        };

        let (reference, ref_cache) = run_wave(None);
        let (disabled, dis_cache) = run_wave(Some(FaultPlan::disabled()));
        assert_eq!(reference.len(), 2, "{ctx}");
        assert_eq!(disabled.len(), 2, "{ctx}");
        for (r, d) in reference.iter().zip(&disabled) {
            assert_eq!(r.id, d.id, "{ctx}");
            assert_eq!(r.decode_tokens, d.decode_tokens, "{ctx}");
            assert_loops_bit_exact(&r.lane, &d.lane, &ctx);
            assert!(!d.lane.fault_counters.any(), "{ctx}");
        }
        assert_eq!(ref_cache.stats(), dis_cache.stats(), "{ctx}");
        dis_cache.check_invariants().unwrap();
    }
}

#[test]
fn fault_seed_determinism_and_lane_wave_fault_site_parity() {
    let plan = FaultPlan { fault_rate: 0.3, ..FaultPlan::smoke() };
    let cfg = tiny_cfg();

    // same seeded plan, served twice lane-mode: identical everything
    let (a, a_cache) = run_loop(&cfg, 4, Some(plan));
    let (b, b_cache) = run_loop(&cfg, 4, Some(plan));
    assert!(a.fault_counters.any(), "a 30% plan over this run must fire");
    assert_eq!(a.fault_counters, b.fault_counters, "replay determinism");
    assert_loops_bit_exact(&a, &b, "replay");
    assert_eq!(a_cache.stats(), b_cache.stats());

    // the same request waved (batch of one) hits the same fault sites:
    // the injector is keyed by the per-request seed and per-request
    // token index, not by engine mode
    let mut wcfg = cfg.clone();
    wcfg.fault = Some(plan);
    let cache = sharded(&wcfg, 4);
    let mut eng = WaveEngine::new(Arc::clone(&cache), 1);
    let be =
        CostModelBackend::new(&wcfg.desc, TraceParams::default(), PREFILL_TOKENS, wcfg.seed);
    eng.admit(0, wcfg, be, PREFILL_TOKENS, DECODE_TOKENS).unwrap();
    let mut done = Vec::new();
    while !eng.is_idle() {
        done.extend(eng.step_wave().unwrap());
    }
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].lane.fault_counters, a.fault_counters, "lane/wave fault parity");
    assert_loops_bit_exact(&done[0].lane, &a, "lane/wave under faults");

    // a different plan seed is a different chaos trace
    let other = FaultPlan { seed: plan.seed ^ 0xDEAD_BEEF, ..plan };
    let (c, _) = run_loop(&cfg, 4, Some(other));
    assert_ne!(
        c.fault_counters, a.fault_counters,
        "distinct plan seeds must sample distinct fault sites"
    );
}

#[test]
fn seeded_chaos_run_completes_clean_with_every_failure_accounted() {
    for shards in [1usize, 4] {
        let ctx = format!("shards {shards}");
        let plan = FaultPlan { fault_rate: 0.5, spike_rate: 0.2, ..FaultPlan::smoke() };
        let (lp, cache) = run_loop(&tiny_cfg(), shards, Some(plan));
        cache.check_invariants().unwrap();

        // every decode step completed despite the injected chaos
        assert_eq!(lp.ledger.decode_steps, DECODE_TOKENS as u64, "{ctx}");
        let fc = &lp.fault_counters;
        assert!(fc.any(), "{ctx}: a 50% plan must fire");
        assert!(fc.retries > 0, "{ctx}: flaky sites always cost one retry");
        assert!(fc.extra_flash_bytes > 0, "{ctx}");
        assert!(fc.retry_energy_j > 0.0, "{ctx}: recovery is charged, not free");
        // every persistent failure resolved through a graceful arm
        assert!(
            fc.failed <= fc.degraded + lp.counters.n_substituted + lp.counters.n_dropped,
            "{ctx}: failed {} degraded {} substituted {} dropped {}",
            fc.failed,
            fc.degraded,
            lp.counters.n_substituted,
            lp.counters.n_dropped
        );
        // recovery traffic is inside the ledger, not a side channel
        assert!(lp.ledger.flash_bytes >= fc.extra_flash_bytes, "{ctx}");
    }
}
