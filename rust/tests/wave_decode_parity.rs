//! Wave-decode parity + cross-request fetch aggregation.
//!
//! Two properties anchor the wave engine:
//!
//! * **batch = 1 is bit-exact** with the per-request `ServeLoop` path on
//!   the same sharded cache topology — token counts, expert counters,
//!   miss/hit statistics, steady-state bytes, fetch counts, and simulated
//!   energies are all EQUAL (not approximately equal: the wave step is
//!   the same op sequence, so the floats match bit for bit);
//! * **co-routed requests share fetches**: N requests routed to the same
//!   experts in one wave pay the flash bill exactly once — the first
//!   walk fills, every later walk hits the just-filled slice.

use std::sync::Arc;

use anyhow::Result;

use slicemoe::cache::{ShardedSliceCache, WarmupStrategy};
use slicemoe::memhier::Phase;
use slicemoe::model::ModelDesc;
use slicemoe::serve::{
    CostModelBackend, ExecPlan, ExpertBackend, ServeConfig, ServeLoop, WaveEngine,
};
use slicemoe::sim::TraceParams;

fn tiny_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::gsm8k_default(ModelDesc::tiny());
    cfg.cache_bytes = cfg.unit_bytes() * 8;
    cfg
}

fn sharded(cfg: &ServeConfig, shards: usize) -> Arc<ShardedSliceCache> {
    let mut c = ShardedSliceCache::new(cfg.cache_bytes, shards);
    c.set_heterogeneous(cfg.heterogeneous_lsb);
    Arc::new(c)
}

#[test]
fn wave_batch_of_one_is_bit_exact_with_serve_loop() {
    // both the unconstrained path (union-of-shards txns) and an active
    // miss budget (all-shard txns + salvage) must reduce to the
    // per-request op sequence at batch = 1
    for shards in [1usize, 4] {
        for constraint in [f64::INFINITY, 0.05] {
            let ctx = format!("shards {shards}, constraint {constraint}");
            let mut cfg = tiny_cfg();
            cfg.constraint = constraint;

            // per-request reference on a fresh sharded cache
            let ref_cache = sharded(&cfg, shards);
            let mut reference =
                ServeLoop::with_sharded_cache(cfg.clone(), Arc::clone(&ref_cache));
            let mut be =
                CostModelBackend::new(&cfg.desc, TraceParams::default(), 32, cfg.seed);
            reference.prefill(&mut be, 32).unwrap();
            for _ in 0..24 {
                reference.decode_token(&mut be).unwrap();
            }

            // wave engine, batch = 1, fresh identical cache + backend
            let cache = sharded(&cfg, shards);
            let mut eng = WaveEngine::new(Arc::clone(&cache), 1);
            let be = CostModelBackend::new(&cfg.desc, TraceParams::default(), 32, cfg.seed);
            eng.admit(0, cfg.clone(), be, 32, 24).unwrap();
            let mut done = Vec::new();
            while !eng.is_idle() {
                done.extend(eng.step_wave().unwrap());
            }
            assert_eq!(done.len(), 1, "{ctx}");
            let mut d = done.pop().unwrap();
            assert_eq!(d.decode_tokens, 24, "{ctx}");
            let w = &mut d.lane;

            assert_eq!(w.ledger.decode_steps, reference.ledger.decode_steps, "{ctx}");
            assert_eq!(w.prefill_tokens, reference.prefill_tokens, "{ctx}");
            assert_eq!(w.counters.n_high, reference.counters.n_high, "{ctx}");
            assert_eq!(w.counters.n_low, reference.counters.n_low, "{ctx}");
            assert_eq!(w.counters.n_dropped, reference.counters.n_dropped, "{ctx}");
            assert_eq!(
                w.counters.n_substituted,
                reference.counters.n_substituted,
                "{ctx}"
            );
            assert_eq!(w.counters.n_degraded, reference.counters.n_degraded, "{ctx}");
            assert_eq!(w.counters.n_critical, reference.counters.n_critical, "{ctx}");
            assert_eq!(w.steady_accesses, reference.steady_accesses, "{ctx}");
            assert_eq!(w.steady_flash, reference.steady_flash, "{ctx}");
            assert_eq!(
                w.decode_flash_fetches,
                reference.decode_flash_fetches,
                "{ctx}"
            );
            assert_eq!(w.miss_rate(), reference.miss_rate(), "{ctx}");
            assert_eq!(
                w.ledger.decode_energy_j(),
                reference.ledger.decode_energy_j(),
                "{ctx}"
            );
            assert_eq!(
                w.ledger.prefill_energy_j(),
                reference.ledger.prefill_energy_j(),
                "{ctx}"
            );
            assert_eq!(w.hit_rates(), reference.hit_rates(), "{ctx}");
            assert_eq!(cache.stats(), ref_cache.stats(), "{ctx}");
            cache.check_invariants().unwrap();
        }
    }
}

/// Deterministic backend: every request gates to the SAME fixed
/// probability vector, so a whole wave co-routes to one top-k set.
struct FixedGate {
    prefill_tokens: usize,
    probs: Vec<f64>,
}

impl ExpertBackend for FixedGate {
    fn gate(&mut self, phase: Phase, _layer: usize) -> Result<Vec<Vec<f64>>> {
        Ok(match phase {
            Phase::Prefill => vec![self.probs.clone(); self.prefill_tokens],
            _ => vec![self.probs.clone()],
        })
    }

    fn run_experts(&mut self, _phase: Phase, _layer: usize, _plan: &ExecPlan) -> Result<()> {
        Ok(())
    }
}

fn fixed_gate(cfg: &ServeConfig, prefill_tokens: usize) -> FixedGate {
    let n = cfg.desc.n_experts;
    let raw: Vec<f64> = (0..n).map(|e| 1.0 / (e + 1) as f64).collect();
    let total: f64 = raw.iter().sum();
    FixedGate {
        prefill_tokens,
        probs: raw.into_iter().map(|p| p / total).collect(),
    }
}

#[test]
fn co_routed_requests_pay_the_fetch_bill_exactly_once() {
    // Empty warmup clears the cache at the prefill->decode boundary, so
    // the first decode token starts cold and every routed slice misses
    let mut cfg = tiny_cfg();
    cfg.warmup = WarmupStrategy::Empty;

    // solo reference: the flash-fetch bill of ONE cold request's token
    let mut eng = WaveEngine::new(sharded(&cfg, 4), 1);
    eng.admit(0, cfg.clone(), fixed_gate(&cfg, 8), 8, 1).unwrap();
    let done = eng.step_wave().unwrap();
    assert_eq!(done.len(), 1);
    let solo = done[0].lane.decode_flash_fetches;
    assert!(solo > 0, "a cold decode token must fetch its slices");

    // four co-routed requests in ONE wave: the first (admission order)
    // pays exactly the solo bill, the other three hit the just-filled
    // slices and fetch nothing
    let mut eng = WaveEngine::new(sharded(&cfg, 4), 4);
    for id in 0..4 {
        eng.admit(id, cfg.clone(), fixed_gate(&cfg, 8), 8, 1).unwrap();
    }
    let mut done = eng.step_wave().unwrap();
    assert_eq!(done.len(), 4);
    done.sort_by_key(|d| d.id);
    assert_eq!(
        done[0].lane.decode_flash_fetches, solo,
        "first co-routed request pays the solo fetch bill, once"
    );
    for d in &done[1..] {
        assert_eq!(
            d.lane.decode_flash_fetches, 0,
            "request {} re-paid fetches the wave already filled",
            d.id
        );
    }
    // per-token compute is still charged per request: everyone executed
    for d in &done {
        let c = d.lane.counters;
        assert_eq!(
            c.n_high + c.n_low + c.n_dropped,
            (cfg.desc.n_layers * cfg.desc.top_k) as u64,
            "request {} expert-execution conservation",
            d.id
        );
    }
}
