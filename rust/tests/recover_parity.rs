//! Crash-safety parity (mirrors `fault_parity.rs` / `control_parity.rs`
//! for the recovery plane).
//!
//! Four contracts anchor warm restarts:
//!
//! * **snapshot→restore identity**: an SMRM residency manifest survives
//!   the disk roundtrip bit-identically at shards {1, 4}, restores the
//!   exact key/byte/pin set into a fresh cache (same or different shard
//!   count), and degrades to a pinned-first prefix under a short
//!   restore budget;
//! * **loud rejection**: every single-byte flip and every truncation of
//!   a manifest fails parsing (whole-file CRC), and torn or corrupted
//!   journals fail record-by-record — never a silent partial restore,
//!   never an attacker-sized allocation;
//! * **bit-exact re-execution**: a request rebuilt from its journal
//!   admit record serves bit-identically to the uninterrupted run —
//!   same output bytes, energy, miss rate — with fault injection off
//!   and on;
//! * **restart recovery**: `run_restart_recovery` re-drives the
//!   journal's pending request, and the manifest-warmed cache strictly
//!   beats the cold-start control on early-decode miss rate; the
//!   scrubber's repair traffic reconciles against the Ledger.

use std::sync::Arc;

use slicemoe::cache::ShardedSliceCache;
use slicemoe::fault::FaultPlan;
use slicemoe::memhier::HwSpec;
use slicemoe::model::{ModelDesc, SliceKey};
use slicemoe::recover::{
    Journal, PendingRequest, ResidencyManifest, ScrubConfig, Scrubber, SnapshotSink,
};
use slicemoe::serve::ServeConfig;
use slicemoe::server::{
    request_seed, Backend, CostModelServerBackend, Request, Response, SharedCacheHandle,
};
use slicemoe::sim::TraceParams;
use slicemoe::workload::run_restart_recovery;

fn tiny_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::gsm8k_default(ModelDesc::tiny());
    cfg.cache_bytes = cfg.unit_bytes() * 8;
    cfg
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("recover_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A cache with a mixed MSB/LSB population and one pinned entry,
/// generously sized so nothing evicts regardless of shard hashing.
fn populated_cache(shards: usize) -> ShardedSliceCache {
    let cache = ShardedSliceCache::new(12_000, shards);
    for e in 0..8usize {
        cache.ensure(SliceKey::msb(e % 4, e), 300);
        if e % 3 == 0 {
            cache.ensure(SliceKey::lsb(e % 4, e), 150);
        }
    }
    cache.pin(SliceKey::msb(0, 0), true);
    cache
}

#[test]
fn snapshot_restore_roundtrip_is_identity_at_shards_1_and_4() {
    for shards in [1usize, 4] {
        let cache = populated_cache(shards);
        let m = ResidencyManifest::capture(&cache);
        assert!(m.entries() > 0);
        let dir = tmp_dir(&format!("roundtrip{shards}"));
        let path = dir.join(SnapshotSink::FILE_NAME);
        m.write(&path).unwrap();
        let loaded = ResidencyManifest::load(&path).unwrap();
        assert_eq!(loaded, m, "disk roundtrip is identity (shards={shards})");

        // same-topology restore: the exact key/byte/pin set comes back
        let fresh = ShardedSliceCache::new(cache.capacity(), shards);
        let rs = loaded.restore_into(&fresh, None);
        assert_eq!(rs.restored, m.entries());
        assert_eq!(rs.restored_bytes, m.resident_bytes());
        assert_eq!(rs.dropped, 0);
        for (_, entries) in &m.shards {
            for e in entries {
                assert!(fresh.peek(e.key), "{:?} resident after restore", e.key);
                assert_eq!(fresh.is_pinned(e.key), e.pinned, "{:?}", e.key);
            }
        }
        let recap = ResidencyManifest::capture(&fresh);
        assert_eq!(recap.entries(), m.entries());
        assert_eq!(recap.resident_bytes(), m.resident_bytes());

        // cross-topology restore (global recency merge) loses nothing
        let cross = ShardedSliceCache::new(cache.capacity(), 2);
        assert_eq!(loaded.restore_into(&cross, None).restored, m.entries());

        // short restore budget: degraded prefix, pinned entries first
        let tight = ShardedSliceCache::new(cache.capacity(), shards);
        let budget = m.resident_bytes() / 2;
        let rs = loaded.restore_into(&tight, Some(budget));
        assert!(rs.restored_bytes <= budget, "budget is a hard cap");
        assert!(rs.dropped > 0, "half the bytes cannot all fit");
        assert!(tight.is_pinned(SliceKey::msb(0, 0)), "pins restore first");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn manifest_rejects_every_single_byte_flip_and_truncation() {
    let buf = ResidencyManifest::capture(&populated_cache(2)).to_bytes();
    assert!(ResidencyManifest::parse(&buf).is_ok());
    // the whole-file CRC makes every flip loud, wherever it lands
    // (magic, counts, entry payload, or the trailer itself)
    for i in 0..buf.len() {
        let mut b = buf.clone();
        b[i] ^= 0xff;
        assert!(ResidencyManifest::parse(&b).is_err(), "byte flip at {i} must fail parsing");
    }
    for len in 0..buf.len() {
        assert!(
            ResidencyManifest::parse(&buf[..len]).is_err(),
            "truncation to {len} bytes must fail parsing"
        );
    }
}

#[test]
fn journal_rejects_bad_magic_torn_tail_and_flipped_payload() {
    let dir = tmp_dir("corrupt");
    let jpath = dir.join(Journal::FILE_NAME);
    let j = Journal::create(&jpath, 0xBA5E).unwrap();
    j.record_admit(&PendingRequest {
        id: 7,
        seed: 1,
        prompt: vec![1, 2, 3],
        decode_tokens: 4,
        slo: None,
        bias: None,
    })
    .unwrap();
    drop(j);
    let buf = std::fs::read(&jpath).unwrap();
    assert_eq!(Journal::parse(&buf).unwrap().pending.len(), 1);

    let mut bad_magic = buf.clone();
    bad_magic[0] ^= 0xff;
    assert!(Journal::parse(&bad_magic).is_err(), "bad magic");
    assert!(
        Journal::parse(&buf[..buf.len() - 1]).is_err(),
        "torn record tail (crash mid-append) must fail, not half-parse"
    );
    // any payload byte flip breaks the record CRC (last 8 bytes of the
    // record are the CRC trailer; len-9 is the final payload byte)
    let mut flipped = buf.clone();
    let i = flipped.len() - 9;
    flipped[i] ^= 0xff;
    assert!(Journal::parse(&flipped).is_err(), "payload flip at {i}");
    let _ = std::fs::remove_dir_all(&dir);
}

fn serve_once(cfg: &ServeConfig, base_seed: u64, req: &Request) -> Response {
    let mut b = CostModelServerBackend::new(cfg.clone(), TraceParams::default(), base_seed);
    b.serve(req).unwrap()
}

#[test]
fn journal_redriven_request_is_bit_exact_with_uninterrupted_serving() {
    for (tag, fault) in [
        ("off", None),
        ("on", Some(FaultPlan { fault_rate: 0.3, ..FaultPlan::smoke() })),
    ] {
        let mut cfg = tiny_cfg();
        cfg.fault = fault;
        let base_seed = 0x0DD_5EED;
        let req = Request::new(11, vec![7u8; 24], 16);
        let direct = serve_once(&cfg, base_seed, &req);

        // journal the admission, "crash", reload, rebuild, re-serve
        let dir = tmp_dir(&format!("redrive_{tag}"));
        let jpath = dir.join(Journal::FILE_NAME);
        let j = Journal::create(&jpath, base_seed).unwrap();
        j.record_admit(&PendingRequest {
            id: req.id,
            seed: request_seed(base_seed, req.id),
            prompt: req.prompt.clone(),
            decode_tokens: req.decode_tokens as u32,
            slo: req.slo,
            bias: req.bias,
        })
        .unwrap();
        drop(j);
        let state = Journal::load(&jpath).unwrap();
        assert_eq!(state.pending.len(), 1, "faults {tag}");
        let p = &state.pending[0];
        let rebuilt = Request {
            id: p.id,
            prompt: p.prompt.clone(),
            decode_tokens: p.decode_tokens as usize,
            bias: p.bias,
            slo: p.slo,
        };
        let redriven = serve_once(&cfg, state.base_seed, &rebuilt);

        assert_eq!(direct.output, redriven.output, "faults {tag}");
        assert_eq!(direct.decode_tokens, redriven.decode_tokens, "faults {tag}");
        assert_eq!(direct.decode_energy_j, redriven.decode_energy_j, "faults {tag}");
        assert_eq!(direct.miss_rate, redriven.miss_rate, "faults {tag}");
        assert_eq!(direct.fault_retries, redriven.fault_retries, "faults {tag}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn restart_recovery_warm_beats_cold_and_reexecutes_pending() {
    let cfg = tiny_cfg();
    let base_seed = 0x4269;
    let dir = tmp_dir("restart");
    // the "dead" run: three admits journaled, two served to completion
    // over a sharded cache, one manifest written — then nothing (the
    // crash needs no simulation; the files ARE the evidence)
    let cache = CostModelServerBackend::sharded_cache_for(&cfg, 4);
    let mut b = CostModelServerBackend::new(cfg.clone(), TraceParams::default(), base_seed);
    b.shared_cache = Some(SharedCacheHandle::Sharded(Arc::clone(&cache)));
    let j = Journal::create(&dir.join(Journal::FILE_NAME), base_seed).unwrap();
    for id in 0..3u64 {
        j.record_admit(&PendingRequest {
            id,
            seed: request_seed(base_seed, id),
            prompt: vec![id as u8; 24],
            decode_tokens: 12,
            slo: None,
            bias: None,
        })
        .unwrap();
    }
    for id in 0..2u64 {
        b.serve(&Request::new(id, vec![id as u8; 24], 12)).unwrap();
        j.record_complete(id).unwrap();
    }
    ResidencyManifest::capture(&cache).write(&dir.join(SnapshotSink::FILE_NAME)).unwrap();
    drop(j);

    let rec = run_restart_recovery(&dir, &cfg, TraceParams::default(), None, None).unwrap();
    assert_eq!(rec.pending, 1, "two of three admits completed");
    assert_eq!(rec.reexecuted, 1);
    assert_eq!(rec.reexec_errors, 0);
    assert!(rec.restored_entries > 0, "the manifest restored residency");
    assert!(rec.cold_early_lookups > 0 && rec.warm_early_lookups > 0);
    assert!(
        rec.warm_early_miss_rate() < rec.cold_early_miss_rate(),
        "manifest warmup must beat a cold start: warm {} vs cold {}",
        rec.warm_early_miss_rate(),
        rec.cold_early_miss_rate()
    );
    assert!(rec.scrub_scanned > 0, "restart runs a full scrub lap");
    assert_eq!(rec.scrub_repaired, 0, "no rot configured");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scrubber_repairs_forced_corruption_and_ledger_reconciles() {
    let cfg = tiny_cfg();
    let cache = CostModelServerBackend::sharded_cache_for(&cfg, 2);
    let mut b = CostModelServerBackend::new(cfg.clone(), TraceParams::default(), 0x5EED);
    b.shared_cache = Some(SharedCacheHandle::Sharded(Arc::clone(&cache)));
    b.serve(&Request::new(0, vec![3u8; 24], 12)).unwrap();

    let scrubber = Scrubber::new(
        Arc::clone(&cache),
        ScrubConfig::default(),
        FaultPlan::disabled(),
        HwSpec::paper(),
    );
    let victim = cache
        .export_residency()
        .into_iter()
        .flat_map(|(_, es)| es)
        .next()
        .expect("the served request left residency behind");
    scrubber.inject_corruption(victim.key);
    let mut resident = 0u64;
    for (_, v) in cache.export_residency() {
        resident += v.len() as u64;
    }
    for _ in 0..(resident / 64 + 2) {
        let _ = scrubber.tick(0);
    }
    let st = scrubber.stats();
    assert_eq!(st.repaired, 1, "the corrupt slice was evicted and refetched");
    assert_eq!(st.repaired_bytes, victim.bytes);
    assert_eq!(st.repair_failed, 0);
    assert!(cache.peek(victim.key), "repaired slice is resident again");
    let ledger = scrubber.ledger();
    assert_eq!(ledger.flash_fetches, 1);
    assert_eq!(ledger.flash_bytes, victim.bytes, "repair bytes reconcile against the Ledger");
}
