//! Overload-control parity + scripted ladder dynamics + breaker storm
//! (mirrors `fault_parity.rs` for the control plane).
//!
//! Three contracts anchor the controller:
//!
//! * **off/idle bit-exactness**: a fleet with a controller attached but
//!   never engaged (level 0) produces the bit-identical simulated
//!   results — energies, miss rates, fetch counts — as a fleet built
//!   without one, lane-mode and waved, at shards {1, 4};
//! * **deterministic ladder dynamics**: a `Clock::Manual`-scripted
//!   overload engages the degradation ladder level by level, holds in
//!   the hysteresis band without oscillating, actuates (constraint
//!   tightening, precision bias, token-bucket refusal), and releases
//!   one level at a time back to identity shaping;
//! * **breaker storm accounting**: a seeded persistent-failure storm
//!   trips the fetch circuit breaker, skips while open, half-open
//!   probes after cooldown, closes on recovery, replays bit-identically,
//!   and every retry joule it saves reconciles against the Ledger.

use std::sync::Arc;

use slicemoe::cache::ShardedSliceCache;
use slicemoe::control::{ControlConfig, ControlSignals, Controller};
use slicemoe::fault::{BreakerConfig, FaultPlan};
use slicemoe::model::ModelDesc;
use slicemoe::router::Precision;
use slicemoe::serve::{CostModelBackend, ServeConfig, ServeLoop, WaveEngine};
use slicemoe::server::{
    request_seed, summarize, CostModelServerBackend, Request, Response, ServerHandle,
    SharedCacheHandle,
};
use slicemoe::sim::TraceParams;
use slicemoe::telemetry::Clock;

const PREFILL_TOKENS: usize = 24;
const DECODE_TOKENS: usize = 16;
const N_REQUESTS: u64 = 6;

fn tiny_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::gsm8k_default(ModelDesc::tiny());
    cfg.cache_bytes = cfg.unit_bytes() * 8;
    cfg
}

fn sharded(cfg: &ServeConfig, shards: usize) -> Arc<ShardedSliceCache> {
    let mut c = ShardedSliceCache::new(cfg.cache_bytes, shards);
    c.set_heterogeneous(cfg.heterogeneous_lsb);
    Arc::new(c)
}

/// A single-lane fleet over a shared sharded cache (one lane so the
/// serving order — and therefore the shared-cache trajectory — is
/// deterministic), optionally with a controller attached.
fn lane_fleet(shards: usize, ctl: Option<Arc<Controller>>) -> Vec<Response> {
    let cfg = tiny_cfg();
    let cache = SharedCacheHandle::Sharded(CostModelServerBackend::sharded_cache_for(
        &cfg, shards,
    ));
    let factory_ctl = ctl.clone();
    let mut h = ServerHandle::start(1, 16, move |_lane| {
        let mut b = CostModelServerBackend::new(cfg.clone(), TraceParams::default(), 0xC0DE);
        b.shared_cache = Some(cache.clone());
        if let Some(c) = &factory_ctl {
            b = b.with_controller(Arc::clone(c));
        }
        Ok(b)
    });
    if let Some(c) = &ctl {
        h.attach_controller(Arc::clone(c));
    }
    for id in 0..N_REQUESTS {
        h.submit(Request::new(id, vec![0u8; PREFILL_TOKENS], DECODE_TOKENS))
            .unwrap();
    }
    let mut out: Vec<Response> = (0..N_REQUESTS).map(|_| h.recv().unwrap()).collect();
    h.shutdown();
    out.sort_by_key(|r| r.id);
    out
}

/// Every deterministic (simulated, non-wall-clock) response field.
fn assert_responses_bit_exact(a: &[Response], b: &[Response], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{ctx}");
        assert_eq!(x.decode_tokens, y.decode_tokens, "{ctx} req {}", x.id);
        assert_eq!(x.decode_energy_j, y.decode_energy_j, "{ctx} req {}", x.id);
        assert_eq!(x.miss_rate, y.miss_rate, "{ctx} req {}", x.id);
        assert_eq!(x.steady_flash_bytes, y.steady_flash_bytes, "{ctx} req {}", x.id);
        assert_eq!(x.steady_norm_bytes, y.steady_norm_bytes, "{ctx} req {}", x.id);
        assert_eq!(x.decode_flash_fetches, y.decode_flash_fetches, "{ctx} req {}", x.id);
        assert_eq!(x.n_experts, y.n_experts, "{ctx} req {}", x.id);
        assert_eq!(x.n_degraded, y.n_degraded, "{ctx} req {}", x.id);
        assert_eq!(x.fault_retries, y.fault_retries, "{ctx} req {}", x.id);
        assert_eq!(x.fault_failed, y.fault_failed, "{ctx} req {}", x.id);
        assert_eq!(x.retry_energy_j, y.retry_energy_j, "{ctx} req {}", x.id);
        assert_eq!(x.breaker_skips, y.breaker_skips, "{ctx} req {}", x.id);
        assert_eq!(x.breaker_trips, y.breaker_trips, "{ctx} req {}", x.id);
        assert!(!x.shed && !y.shed, "{ctx}");
        assert!(!x.refused && !y.refused, "{ctx}");
    }
    let (sa, sb) = (summarize(a), summarize(b));
    assert_eq!(sa.decode_energy_j, sb.decode_energy_j, "{ctx}");
    assert_eq!(sa.combined_miss_rate, sb.combined_miss_rate, "{ctx}");
    assert_eq!(sa.decode_tokens, sb.decode_tokens, "{ctx}");
}

#[test]
fn lane_fleet_is_bit_exact_with_controller_attached_but_disengaged() {
    for shards in [1usize, 4] {
        let ctx = format!("shards {shards}");
        let plain = lane_fleet(shards, None);
        // default watermarks: 6 requests over a 16-deep queue peak in
        // the hysteresis band, so the ladder never engages
        let ctl = Arc::new(Controller::new(ControlConfig::default()));
        let attached = lane_fleet(shards, Some(Arc::clone(&ctl)));
        assert_responses_bit_exact(&plain, &attached, &ctx);
        assert_eq!(ctl.level(), 0, "{ctx}: the ladder must not have engaged");
        assert_eq!(ctl.stats().engagements, 0, "{ctx}");
        assert_eq!(ctl.stats().refused, 0, "{ctx}");
    }
}

/// The same bit-exact loop comparison `fault_parity.rs` pins.
fn assert_loops_bit_exact(a: &ServeLoop, b: &ServeLoop, ctx: &str) {
    assert_eq!(a.ledger.decode_steps, b.ledger.decode_steps, "{ctx}");
    assert_eq!(a.counters.n_high, b.counters.n_high, "{ctx}");
    assert_eq!(a.counters.n_low, b.counters.n_low, "{ctx}");
    assert_eq!(a.counters.n_dropped, b.counters.n_dropped, "{ctx}");
    assert_eq!(a.counters.n_substituted, b.counters.n_substituted, "{ctx}");
    assert_eq!(a.counters.n_degraded, b.counters.n_degraded, "{ctx}");
    assert_eq!(a.steady_accesses, b.steady_accesses, "{ctx}");
    assert_eq!(a.steady_flash, b.steady_flash, "{ctx}");
    assert_eq!(a.decode_flash_fetches, b.decode_flash_fetches, "{ctx}");
    assert_eq!(a.miss_rate(), b.miss_rate(), "{ctx}");
    assert_eq!(a.ledger.decode_energy_j(), b.ledger.decode_energy_j(), "{ctx}");
    assert_eq!(a.ledger.flash_bytes, b.ledger.flash_bytes, "{ctx}");
    assert_eq!(a.ledger.flash_fetches, b.ledger.flash_fetches, "{ctx}");
    assert_eq!(a.hit_rates(), b.hit_rates(), "{ctx}");
}

#[test]
fn wave_engine_is_bit_exact_under_level_0_shaping() {
    // the wave path applies `shape_config` per admission; at level 0
    // that must be the identity, co-residency and fetch aggregation
    // included
    for shards in [1usize, 4] {
        let ctx = format!("shards {shards}");
        let run = |ctl: Option<&Controller>| {
            let cfg = tiny_cfg();
            let cache = sharded(&cfg, shards);
            let mut eng = WaveEngine::new(Arc::clone(&cache), 2);
            for id in 0..2u64 {
                let mut rcfg = cfg.clone();
                rcfg.seed = request_seed(cfg.seed, id);
                if let Some(c) = ctl {
                    c.shape_config(&mut rcfg);
                }
                let be = CostModelBackend::new(
                    &rcfg.desc,
                    TraceParams::default(),
                    PREFILL_TOKENS,
                    rcfg.seed,
                );
                eng.admit(id, rcfg, be, PREFILL_TOKENS, DECODE_TOKENS).unwrap();
            }
            let mut done = Vec::new();
            while !eng.is_idle() {
                done.extend(eng.step_wave().unwrap());
            }
            done.sort_by_key(|d| d.id);
            (done, cache)
        };
        let idle = Controller::new(ControlConfig::default());
        let (plain, plain_cache) = run(None);
        let (shaped, shaped_cache) = run(Some(&idle));
        assert_eq!(plain.len(), 2, "{ctx}");
        for (p, s) in plain.iter().zip(&shaped) {
            assert_eq!(p.id, s.id, "{ctx}");
            assert_eq!(p.decode_tokens, s.decode_tokens, "{ctx}");
            assert_loops_bit_exact(&p.lane, &s.lane, &ctx);
        }
        assert_eq!(plain_cache.stats(), shaped_cache.stats(), "{ctx}");
        shaped_cache.check_invariants().unwrap();
    }
}

#[test]
fn scripted_overload_walks_the_ladder_and_releases_with_hysteresis() {
    let (clock, hand) = Clock::manual();
    let ccfg = ControlConfig {
        tick_us: 100,
        up_ticks: 2,
        down_ticks: 3,
        bucket_capacity: 2,
        refill_per_tick: 1,
        ..ControlConfig::default()
    };
    let ctl = Controller::new(ccfg);
    let hot = ControlSignals { queue_len: 8, queue_capacity: 8, ..Default::default() };
    let calm = ControlSignals { queue_len: 0, queue_capacity: 8, ..Default::default() };
    let mid = ControlSignals { queue_len: 4, queue_capacity: 8, ..Default::default() };
    let base = ServeConfig::gsm8k_default(ModelDesc::tiny());

    ctl.observe(clock.now_us(), &calm); // arm the tick
    // engage level by level: 2 hot ticks per upward step
    let mut trajectory = Vec::new();
    for _ in 0..6 {
        hand.advance_us(100);
        ctl.observe(clock.now_us(), &hot);
        trajectory.push(ctl.level());
    }
    assert_eq!(trajectory, vec![0, 1, 1, 2, 2, 3], "level-by-level engagement");

    // level 3 actuation: tightened constraint, low-bit bias, token bucket
    let mut shaped = base.clone();
    ctl.shape_config(&mut shaped);
    assert!(shaped.constraint <= ccfg.overload_constraint, "constraint tightened");
    match shaped.router.dbsc {
        Some(d) => assert_eq!(d.max_critical, 0, "DBSC biased to the MSB prefix"),
        None => assert_eq!(shaped.router.uniform_precision, Precision::Low),
    }
    assert!(ctl.try_admit() && ctl.try_admit(), "bucket capacity 2");
    assert!(!ctl.try_admit(), "dry bucket refuses");
    assert_eq!(ctl.stats().refused, 1);

    // hysteresis band: mid occupancy holds level 3 indefinitely
    for _ in 0..8 {
        hand.advance_us(100);
        ctl.observe(clock.now_us(), &mid);
        assert_eq!(ctl.level(), 3, "band must hold, not oscillate");
    }

    // release: one level per 3 calm ticks, 9 ticks to fully clear
    let mut release = Vec::new();
    for _ in 0..9 {
        hand.advance_us(100);
        ctl.observe(clock.now_us(), &calm);
        release.push(ctl.level());
    }
    assert_eq!(release, vec![3, 3, 2, 2, 2, 1, 1, 1, 0], "stepwise release");
    let st = ctl.stats();
    assert_eq!((st.engagements, st.releases, st.max_level), (3, 1, 3));

    // a single post-release hot blip (below up_ticks) must not re-engage
    hand.advance_us(100);
    ctl.observe(clock.now_us(), &hot);
    hand.advance_us(100);
    ctl.observe(clock.now_us(), &calm);
    assert_eq!(ctl.level(), 0, "blip shorter than up_ticks is ignored");
    assert_eq!(ctl.stats().engagements, 3);

    // back at level 0 the shaping is the identity again
    let mut again = base.clone();
    ctl.shape_config(&mut again);
    assert_eq!(again.constraint, base.constraint);
    assert_eq!(again.router.dbsc, base.router.dbsc);
    assert_eq!(again.router.uniform_precision, base.router.uniform_precision);
}

#[test]
fn seeded_storm_trips_half_opens_closes_and_reconciles_the_ledger() {
    // persistent-failure storm: every flaky site exhausts its retries
    let storm = FaultPlan { fault_rate: 0.4, retry_fail_p: 1.0, ..FaultPlan::smoke() };
    let decode = 48usize;
    let run = |breaker: Option<BreakerConfig>| {
        let mut cfg = tiny_cfg();
        cfg.fault = Some(storm);
        cfg.breaker = breaker;
        let cache = sharded(&cfg, 4);
        let mut lp = ServeLoop::with_sharded_cache(cfg.clone(), Arc::clone(&cache));
        let mut be =
            CostModelBackend::new(&cfg.desc, TraceParams::default(), PREFILL_TOKENS, cfg.seed);
        lp.prefill(&mut be, PREFILL_TOKENS).unwrap();
        for _ in 0..decode {
            lp.decode_token(&mut be).unwrap();
        }
        lp
    };

    let bcfg = BreakerConfig { fail_threshold: 1, cooldown_steps: 2 };
    let unguarded = run(None);
    let a = run(Some(bcfg));
    let b = run(Some(bcfg));

    // every token still served through the storm
    assert_eq!(a.ledger.decode_steps, decode as u64);

    // the full breaker cycle fired: trip -> skip while open -> half-open
    // probe after cooldown -> close once the site's flaky window ends
    let st = a.breaker.as_ref().expect("breaker is live under an active plan").stats();
    assert!(st.trips > 0, "storm must trip: {st:?}");
    assert!(st.skips > 0, "open breaker must skip fetches: {st:?}");
    assert!(st.probes > 0, "cooldown must half-open: {st:?}");
    assert!(st.closes > 0, "recovered sites must close: {st:?}");
    assert_eq!(st.skips, a.fault_counters.breaker_skips, "breaker and walk agree");

    // deterministic replay: identical chaos, identical breaker cycle,
    // identical ledger — bit-exact
    assert_eq!(a.fault_counters, b.fault_counters, "storm replay");
    assert_eq!(st, b.breaker.as_ref().unwrap().stats(), "breaker replay");
    assert_loops_bit_exact(&a, &b, "storm replay");

    // the saved retries are real and the remaining retry joules
    // reconcile against the Ledger (recovery traffic is charged inside
    // flash_bytes, never a side channel)
    assert!(a.fault_counters.retries < unguarded.fault_counters.retries);
    assert!(a.fault_counters.retry_energy_j <= unguarded.fault_counters.retry_energy_j);
    assert!(a.ledger.flash_bytes >= a.fault_counters.extra_flash_bytes);
}
