//! Bench: Fig 9 regeneration — decode energy gain & speed-up across
//! routing schemes and cache sizes (matched-accuracy operating points).

use slicemoe::experiments::fig9;
use slicemoe::model::ModelDesc;
use slicemoe::util::bench::{bench, runner};
use slicemoe::util::threadpool::default_threads;

fn main() {
    let mut report = runner("Fig 9 — energy gain & speed-up");
    let threads = default_threads();
    for desc in [ModelDesc::deepseek_v2_lite(), ModelDesc::qwen15_moe_a27b()] {
        let mut last = None;
        let r = bench(&format!("fig9/{}", desc.name), 0, 2, || {
            last = Some(fig9(&desc, threads));
        });
        report(r);
        if let Some((points, table)) = last {
            print!("{}", table.render());
            let best = points
                .iter()
                .filter(|p| p.scheme == "dbsc+amat")
                .fold((0.0f64, 0.0f64), |a, p| {
                    (a.0.max(p.energy_gain), a.1.max(p.speedup))
                });
            println!(
                "best dbsc+amat vs high-bit Cache-Prior: {:.2}x energy, {:.2}x speed-up\n",
                best.0, best.1
            );
        }
    }
}
