//! Engine (PJRT execution path) benchmarks: per-op latency and end-to-end
//! decode throughput of the tiny LM. The L3 perf target is that the
//! coordinator adds <10% over raw PJRT compute — the per-op numbers here
//! are the denominators for that check (EXPERIMENTS.md §Perf). Results
//! print to stdout AND land in `BENCH_engine.json` (median/MAD per case)
//! so the perf trajectory is tracked across PRs.

use std::path::Path;

use slicemoe::engine::{Engine, Session, SessionConfig};
use slicemoe::quant::MatConfig;
use slicemoe::router::Precision;
use slicemoe::runtime::DeviceTensor;
use slicemoe::util::bench::{bench_units, Reporter};

fn main() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("model_meta.json").exists() {
        println!("bench_engine: artifacts/ missing — run `make artifacts`; skipping");
        return;
    }
    let eng = Engine::load(artifacts, MatConfig::MAT84).expect("load engine");
    let mut report = Reporter::new("engine (PJRT) benchmarks");
    let m = &eng.ws.meta;

    // single expert FFN at each precision (decode shape, T=1)
    {
        let x = vec![0.1f32; m.d_model];
        let x_b = DeviceTensor::from_f32(&eng.rt, &x, &[1, m.d_model]).unwrap();
        for (name, prec) in [
            ("expert fp32", Precision::Full),
            ("expert high (8b planes)", Precision::High),
            ("expert low (4b msb)", Precision::Low),
        ] {
            report.record(bench_units(&format!("op/{name} T=1"), 3, 30, 1.0, || {
                let y = eng.run_expert(0, 0, prec, &x_b.buffer, false).unwrap();
                std::hint::black_box(y);
            }));
        }
    }

    // full decode step through a session (generate 1 token at a time)
    {
        let mut cfg = SessionConfig::dbsc_default(&eng);
        cfg.constraint = 0.05;
        let mut sess = Session::new(&eng, cfg);
        let eval = std::fs::read(artifacts.join("corpus_eval.bin")).unwrap();
        sess.prefill(&eval[..256]).unwrap();
        let mut cur = eval[255];
        report.record(bench_units("session/decode_step (4 layers, top-2)", 2, 48, 1.0, || {
            let (next, _) = sess.decode_step(cur).unwrap();
            cur = next;
        }));
    }

    // prefill throughput
    {
        let eval = std::fs::read(artifacts.join("corpus_eval.bin")).unwrap();
        report.record(bench_units("session/prefill 384 tokens", 0, 3, 384.0, || {
            let mut sess = Session::new(&eng, SessionConfig::dbsc_default(&eng));
            sess.prefill(&eval[..384]).unwrap();
        }));
    }

    report
        .write_json("BENCH_engine.json")
        .expect("write BENCH_engine.json");
}
