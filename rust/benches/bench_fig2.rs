//! Bench: Fig 2 (right) regeneration — high- vs low-bit accuracy across
//! miss-rate constraints. Prints the figure's rows and times the sweep.

use slicemoe::experiments::fig2;
use slicemoe::model::ModelDesc;
use slicemoe::util::bench::{bench, runner};
use slicemoe::util::threadpool::default_threads;

fn main() {
    let mut report = runner("Fig 2 — motivation sweep");
    let threads = default_threads();
    for desc in [ModelDesc::deepseek_v2_lite(), ModelDesc::qwen15_moe_a27b()] {
        let mut last = None;
        let r = bench(&format!("fig2/{}", desc.name), 0, 3, || {
            last = Some(fig2(&desc, threads));
        });
        report(r);
        if let Some((_, table)) = last {
            print!("{}", table.render());
        }
    }
}
