//! Bench: Table 1 regeneration — AMAT PPL measured on the trained tiny LM
//! through the PJRT path. Skips gracefully when artifacts are missing
//! (simulator benches don't need them; this one does).

use std::path::Path;

use slicemoe::engine::Engine;
use slicemoe::experiments::{table1, verify_table1_shape, T1Row};
use slicemoe::quant::MatConfig;
use slicemoe::util::bench::{bench, runner};

fn main() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("model_meta.json").exists() {
        println!("bench_table1: artifacts/ missing — run `make artifacts`; skipping");
        return;
    }
    let eng = Engine::load(artifacts, MatConfig::MAT84).expect("load engine");
    let eval_full = std::fs::read(artifacts.join("corpus_eval.bin")).expect("eval corpus");
    let eval = &eval_full[..2048.min(eval_full.len())];

    let mut report = runner("Table 1 — AMAT PPL (measured)");
    let mats = [(4u32, 2u32), (6, 3), (8, 4)];
    let mut last = None;
    let r = bench("table1/tiny-moe-bytelm", 0, 1, || {
        last = Some(table1(&eng, eval, &mats, &T1Row::all()).expect("table1"));
    });
    report(r);
    if let Some((points, table)) = last {
        print!("{}", table.render());
        let v = verify_table1_shape(&points);
        println!("shape check: {}", if v.is_empty() { "OK".into() } else { format!("{v:?}") });
    }
}
