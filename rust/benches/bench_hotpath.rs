//! Hot-path microbenchmarks (L3 perf deliverable): the operations inside
//! the per-token decode loop. Targets from DESIGN.md §Perf: cache ops O(1)
//! amortized, routing O(E log E) worst case, zero steady-state allocation
//! in the cache. Results print to stdout AND land in `BENCH_hotpath.json`
//! (median/MAD per case) so the perf trajectory is tracked across PRs.

use slicemoe::cache::{CacheOps, ShardedSliceCache, SliceCache};
use slicemoe::memhier::Phase;
use slicemoe::model::descriptor::SliceKey;
use slicemoe::model::ModelDesc;
use slicemoe::quant::{self, MatConfig};
use slicemoe::router::{access_layer, MissBudget, Policy, RouterConfig};
use slicemoe::sim::{run_episode, EpisodeConfig, TraceGenerator, TraceParams};
use slicemoe::util::bench::{bench_units, Reporter};
use slicemoe::util::rng::Rng;

fn main() {
    let mut report = Reporter::new("hot-path microbenchmarks");

    // cache lookup/insert/evict churn at paper scale
    {
        let desc = ModelDesc::deepseek_v2_lite();
        let mat = MatConfig::MAT84;
        let msb = desc.msb_slice_bytes(mat);
        let mut cache = SliceCache::new(msb * 300);
        let mut rng = Rng::new(1);
        let n = 100_000usize;
        report.record(bench_units("cache/lookup+fill churn (100k ops)", 1, 10, n as f64, || {
            for _ in 0..n {
                let key = SliceKey::msb(rng.below(26), rng.below(64));
                if !cache.lookup(key) {
                    let _ = cache.ensure(key, msb);
                }
            }
        }));
    }

    // routing policy selection over 64 experts
    {
        let desc = ModelDesc::deepseek_v2_lite();
        let mut gen = TraceGenerator::new(&desc, TraceParams::default(), 2);
        let probs: Vec<Vec<f64>> = (0..512).map(|_| gen.gate_probs(Phase::Decode, 8)).collect();
        for policy in [
            Policy::TopK,
            Policy::CachePrior { boost: 2.0 },
            Policy::Cumsum { tau: 0.9 },
        ] {
            let name = format!("router/select 512 tokens ({})", policy.name());
            report.record(bench_units(&name, 1, 20, 512.0, || {
                for p in &probs {
                    let r = slicemoe::router::select_experts(policy, p, 6, |e| e % 3 == 0);
                    std::hint::black_box(r);
                }
            }));
        }
    }

    // full access_layer decision (route + cache + budget) per token-layer
    {
        let desc = ModelDesc::deepseek_v2_lite();
        let mat = MatConfig::MAT84;
        let unit = desc.msb_slice_bytes(mat) + desc.lsb_slice_bytes(mat);
        let mut cache = SliceCache::new(unit * 260);
        let mut budget = MissBudget::new(0.05, unit);
        for _ in 0..10 {
            budget.tick();
        }
        let cfg = RouterConfig::dbsc(6);
        let mut gen = TraceGenerator::new(&desc, TraceParams::default(), 3);
        let probs: Vec<Vec<f64>> = (0..512).map(|_| gen.gate_probs(Phase::Decode, 8)).collect();
        report.record(bench_units("access_layer/512 token-layers (dbsc)", 1, 20, 512.0, || {
            for (i, p) in probs.iter().enumerate() {
                let out = access_layer(&cfg, p, i % 26, &desc, mat, &mut cache,
                                       &mut budget, None);
                std::hint::black_box(out);
            }
        }));
    }

    // multi-threaded shared-cache churn: one global mutex vs the
    // lock-striped sharded cache, point ops and batched token-layer
    // transactions. Ops/sec lands as metrics rows so the lanes-scaling
    // curve is tracked across PRs.
    {
        use std::sync::Mutex;
        use std::time::Instant;

        let desc = ModelDesc::deepseek_v2_lite();
        let mat = MatConfig::MAT84;
        let msb = desc.msb_slice_bytes(mat);
        let (layers, experts) = (26usize, 64usize);
        let iters = 60_000usize; // per thread
        let batch = 6usize; // routed experts per simulated token-layer
        const SHARDS: usize = 16;

        let key_of = |r: u64| {
            SliceKey::msb(((r >> 32) as usize) % layers, (r as usize) % experts)
        };
        // run `work(thread_id)` on `threads` OS threads, return elapsed s
        let churn = |threads: usize, work: &(dyn Fn(usize) + Sync)| -> f64 {
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for t in 0..threads {
                    s.spawn(move || work(t));
                }
            });
            t0.elapsed().as_secs_f64()
        };

        for &threads in &[1usize, 2, 4, 8] {
            // -- point ops: one lock acquisition per cache op ------------
            let mutex_cache = Mutex::new(SliceCache::new(msb * 300));
            let wall = churn(threads, &|t| {
                let mut rng = Rng::new(0x7EA0 + t as u64);
                let mut scratch = Vec::new();
                for _ in 0..iters {
                    let key = key_of(rng.next_u64());
                    let mut c = mutex_cache.lock().unwrap();
                    if !c.lookup(key) {
                        let _ = c.ensure_into(key, msb, &mut scratch);
                    }
                    scratch.clear();
                }
            });
            let mutex_point = (threads * iters) as f64 / wall;

            let sharded = ShardedSliceCache::new(msb * 300, SHARDS);
            let wall = churn(threads, &|t| {
                let mut rng = Rng::new(0x7EA0 + t as u64);
                let mut scratch = Vec::new();
                for _ in 0..iters {
                    let key = key_of(rng.next_u64());
                    // one lock acquisition per op, symmetric with the
                    // mutex arm's single guard over lookup+fill
                    sharded.lookup_or_insert(key, msb, &mut scratch);
                    scratch.clear();
                }
            });
            let sharded_point = (threads * iters) as f64 / wall;

            // -- batched txns: one critical section per token-layer ------
            let txn_iters = iters / batch;
            let mutex_cache = Mutex::new(SliceCache::new(msb * 300));
            let wall = churn(threads, &|t| {
                let mut rng = Rng::new(0x7EA0 + t as u64);
                let mut scratch = Vec::new();
                for _ in 0..txn_iters {
                    let keys: Vec<SliceKey> =
                        (0..batch).map(|_| key_of(rng.next_u64())).collect();
                    let mut c = mutex_cache.lock().unwrap();
                    for &key in &keys {
                        if !c.lookup(key) {
                            let _ = c.ensure_into(key, msb, &mut scratch);
                        }
                    }
                    scratch.clear();
                }
            });
            let mutex_txn = (threads * txn_iters * batch) as f64 / wall;

            let sharded = ShardedSliceCache::new(msb * 300, SHARDS);
            let wall = churn(threads, &|t| {
                let mut rng = Rng::new(0x7EA0 + t as u64);
                let mut scratch = Vec::new();
                for _ in 0..txn_iters {
                    let keys: Vec<SliceKey> =
                        (0..batch).map(|_| key_of(rng.next_u64())).collect();
                    let mut txn = sharded.txn(
                        keys.iter().map(|k| sharded.shard_of_expert(k.expert as usize)),
                    );
                    for &key in &keys {
                        if !txn.lookup(key) {
                            let _ = txn.ensure_into(key, msb, &mut scratch);
                        }
                    }
                    drop(txn);
                    scratch.clear();
                }
            });
            let sharded_txn = (threads * txn_iters * batch) as f64 / wall;

            for (name, ops) in [
                ("point/mutex".to_string(), mutex_point),
                (format!("point/sharded{SHARDS}"), sharded_point),
                ("txn/mutex".to_string(), mutex_txn),
                (format!("txn/sharded{SHARDS}"), sharded_txn),
            ] {
                let row = format!("cache-contention/{name}/threads{threads}");
                println!("{row:<46} {ops:>12.0} ops/s");
                report.record_metrics(&row, &[("ops_per_s", ops), ("threads", threads as f64)]);
            }
        }
    }

    // cross-request expert aggregation (wave decode): N co-routed
    // requests walking the same (layer, wave) against one sharded cache,
    // one txn per request vs one shared wave txn. The aggregated walk
    // charges each slice fill once per wave instead of once per request,
    // so fetches/token falls as co-routed width grows; ops/s tracks the
    // walk-loop overhead of the shared transaction.
    {
        use slicemoe::router::{route_layer, walk_layer};
        use std::time::Instant;

        let desc = ModelDesc::deepseek_v2_lite();
        let mat = MatConfig::MAT84;
        let unit = desc.msb_slice_bytes(mat) + desc.lsb_slice_bytes(mat);
        let cfg = RouterConfig::dbsc(6);
        let layers = 26usize;
        let steps = 1024usize; // (token, layer) wave steps per run
        const SHARDS: usize = 8;

        for &width in &[1usize, 4, 16] {
            // per-request decode gate draws, identical across both variants
            let probs: Vec<Vec<Vec<f64>>> = (0..width)
                .map(|r| {
                    let mut gen =
                        TraceGenerator::new(&desc, TraceParams::default(), 0xA6 + r as u64);
                    (0..steps).map(|s| gen.gate_probs(Phase::Decode, s % layers)).collect()
                })
                .collect();

            for (variant, aggregated) in [("per-request", false), ("aggregated", true)] {
                let cache = ShardedSliceCache::new(unit * 96, SHARDS);
                let mut budgets: Vec<MissBudget> =
                    (0..width).map(|_| MissBudget::new(f64::INFINITY, unit)).collect();
                let mut scratch = Vec::new();
                let mut fetches = 0u64;
                let t0 = Instant::now();
                for s in 0..steps {
                    let layer = s % layers;
                    let routes: Vec<_> = (0..width)
                        .map(|r| route_layer(&cfg, &probs[r][s], &budgets[r], |_| false))
                        .collect();
                    if aggregated {
                        let mut txn = cache.txn(routes.iter().flat_map(|rt| {
                            rt.routed.iter().map(|x| cache.shard_of_expert(x.expert))
                        }));
                        for (r, route) in routes.into_iter().enumerate() {
                            let out = walk_layer(
                                &cfg, route, &probs[r][s], layer, &desc, mat, &mut txn,
                                &mut budgets[r], None, &mut scratch,
                            );
                            fetches += out.flash_fetches;
                        }
                    } else {
                        for (r, route) in routes.into_iter().enumerate() {
                            let mut txn = cache.txn(
                                route.routed.iter().map(|x| cache.shard_of_expert(x.expert)),
                            );
                            let out = walk_layer(
                                &cfg, route, &probs[r][s], layer, &desc, mat, &mut txn,
                                &mut budgets[r], None, &mut scratch,
                            );
                            fetches += out.flash_fetches;
                        }
                    }
                }
                let wall = t0.elapsed().as_secs_f64();
                let walks = (steps * width) as f64;
                let ops = walks / wall;
                let fpt = fetches as f64 / walks;
                let row = format!("wave-aggregation/{variant}/width{width}");
                println!("{row:<46} {ops:>12.0} ops/s  {fpt:.4} fetches/token");
                report.record_metrics(
                    &row,
                    &[("ops_per_s", ops), ("fetches_per_token", fpt), ("width", width as f64)],
                );
            }
        }
    }

    // flight-recorder overhead on the decode loop: the identical
    // 48-token decode with the recorder disabled (the default), enabled
    // (per-request plant with an ample ring — the serve-path
    // configuration), and ring-saturated (capacity 32, so nearly every
    // event takes the drop-and-count branch). tokens/s per variant lands
    // in the BENCH JSON; the off↔on gap is the observation-only
    // overhead budget, and saturated must never be slower than on
    // (dropping is cheaper than recording).
    {
        use slicemoe::serve::{CostModelBackend, ServeConfig, ServeLoop};
        use slicemoe::telemetry::{Clock, Recorder};

        let mut cfg = ServeConfig::gsm8k_default(ModelDesc::deepseek_v2_lite());
        cfg.cache_bytes = cfg.unit_bytes() * 96;
        let tokens = 48usize;

        for variant in ["off", "on", "saturated"] {
            let name = format!("telemetry/decode 48 tokens (recorder {variant})");
            let mut lp = ServeLoop::new(cfg.clone());
            let mut be =
                CostModelBackend::new(&cfg.desc, TraceParams::default(), 64, cfg.seed);
            lp.prefill(&mut be, 64).unwrap();
            report.record(bench_units(&name, 1, 10, tokens as f64, || {
                // fresh per-iteration recorder, exactly as the scheduler
                // plants one per admitted request
                lp.recorder = match variant {
                    "on" => Recorder::enabled(1, Clock::default(), 65_536, 0.1),
                    "saturated" => Recorder::enabled(1, Clock::default(), 32, 0.1),
                    _ => Recorder::disabled(),
                };
                for _ in 0..tokens {
                    lp.decode_token(&mut be).unwrap();
                }
                std::hint::black_box(lp.recorder.dropped_events());
            }));
        }
    }

    // quantization throughput (weight-store build path)
    {
        let mut rng = Rng::new(4);
        let w: Vec<f32> = (0..2048 * 256).map(|_| rng.gauss() as f32 * 0.1).collect();
        report.record(bench_units("quant/asym G32 2048x256 (0.5M weights)", 1, 10,
                                  (2048 * 256) as f64, || {
            let t = quant::quantize_asym(&w, 2048, 256, 8, 32);
            std::hint::black_box(t);
        }));
        let t = quant::quantize_asym(&w, 2048, 256, 8, 32);
        report.record(bench_units("quant/pack 8b codes (0.5M)", 1, 10, (2048 * 256) as f64, || {
            std::hint::black_box(quant::pack_bits(&t.q, 8));
        }));
    }

    // whole simulated episode throughput (the fig8 unit of work)
    {
        let mut cfg = EpisodeConfig::gsm8k_default(ModelDesc::deepseek_v2_lite());
        cfg.prefill_tokens = 500;
        cfg.decode_tokens = 128;
        cfg.serve.constraint = 0.05;
        report.record(bench_units("sim/episode 500+128 tokens (deepseek)", 1, 5,
                                  128.0, || {
            std::hint::black_box(run_episode(&cfg));
        }));
    }

    report
        .write_json("BENCH_hotpath.json")
        .expect("write BENCH_hotpath.json");
}
