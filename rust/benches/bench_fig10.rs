//! Bench: Fig 10 regeneration — cache warmup strategies (Empty /
//! Last-layer / Random / PCW) on a single 512+128-token request.

use slicemoe::experiments::fig10;
use slicemoe::model::ModelDesc;
use slicemoe::util::bench::{bench, runner};
use slicemoe::util::threadpool::default_threads;

fn main() {
    let mut report = runner("Fig 10 — cache warmup strategies");
    let threads = default_threads();
    for desc in [ModelDesc::deepseek_v2_lite(), ModelDesc::qwen15_moe_a27b()] {
        let mut last = None;
        let r = bench(&format!("fig10/{}", desc.name), 0, 3, || {
            last = Some(fig10(&desc, threads));
        });
        report(r);
        if let Some((_, table)) = last {
            print!("{}", table.render());
        }
    }
}
