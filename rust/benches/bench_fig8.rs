//! Bench: Fig 8 regeneration — the full accuracy-vs-miss-rate grid
//! (4 configs x 3 cache sizes x 7 constraints) for both models, plus the
//! Pareto-dominance check.

use slicemoe::experiments::{fig8, fig8_pareto_score};
use slicemoe::model::ModelDesc;
use slicemoe::util::bench::{bench, runner};
use slicemoe::util::threadpool::default_threads;

fn main() {
    let mut report = runner("Fig 8 — accuracy vs high-bit-normalized miss rate");
    let threads = default_threads();
    for desc in [ModelDesc::deepseek_v2_lite(), ModelDesc::qwen15_moe_a27b()] {
        let mut last = None;
        let r = bench(&format!("fig8/{}", desc.name), 0, 2, || {
            last = Some(fig8(&desc, threads));
        });
        report(r);
        if let Some((points, table)) = last {
            print!("{}", table.render());
            let (wins, cells) = fig8_pareto_score(&points);
            println!("dbsc+amat Pareto-dominant in {wins}/{cells} cells\n");
        }
    }
}
