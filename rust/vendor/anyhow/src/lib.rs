//! In-tree, dependency-free subset of the `anyhow` crate.
//!
//! The offline build environment vendors only a handful of crates; this
//! shim makes a bare checkout build without any registry access while
//! keeping the exact API surface the project uses: `Result<T>`, `Error`,
//! `anyhow!`, `bail!`, and the `Context` extension trait on `Result` and
//! `Option`. Errors are stored as a flattened message chain (newest
//! context first); `{e}` prints the newest message, `{e:#}` the full
//! chain joined with `": "` — matching anyhow's display contract for the
//! formatting this project relies on. Downcasting and backtraces are not
//! implemented (nothing in the tree uses them).

use std::fmt;

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error value.
pub struct Error {
    /// Newest message first; older contexts / root causes follow.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = self.chain;
        chain.insert(0, context.to_string());
        Error { chain }
    }

    /// The message chain from newest context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension, like `anyhow::Context`.
pub trait Context<T, E> {
    /// Attach a context message to the error side.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Attach a lazily-evaluated context message to the error side.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "missing"))
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().context("open config").unwrap_err();
        assert_eq!(format!("{e}"), "open config");
        assert_eq!(format!("{e:#}"), "open config: missing");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Result<i32> = None.with_context(|| format!("no {}", "value"));
        assert_eq!(format!("{}", v.unwrap_err()), "no value");
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
        fn f() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert!(f().is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<i32> {
            let n: i32 = "17".parse()?;
            Ok(n)
        }
        assert_eq!(g().unwrap(), 17);
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
