"""L2 — the tiny MoE language model (build-time JAX).

Two faces of the same model:

* **Training face** (`loss_fn`, `forward_dense`): pure-jnp, differentiable,
  dense top-k routing with a Switch-style load-balancing auxiliary loss
  (the paper notes modern MoEs apply router regularization that *weakens*
  locality — we reproduce that property so the cache sees realistic,
  diverse routing).
* **Serving face** (`embed_step`, `attn_prefill_step`, `attn_decode_step`,
  `gate_step`, `expert_*_step`, `logits_step`): per-op entry points that
  `aot.py` lowers to individual HLO artifacts. The Rust coordinator owns
  routing/caching *between* these ops — that is exactly where SliceMoE's
  contribution lives, so the op boundary is the DBSC decision boundary.

Geometry (TinyConfig) is a scaled-down DeepSeek-V2-Lite-shaped MoE:
byte-level vocab, 4 layers, 8 routed experts, top-2, SwiGLU experts.
~3.6 M parameters — big enough that AMAT/Trunc/Base orderings are real,
small enough to train on CPU at build time.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import amat_ffn as kernels
from .kernels import ref

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TinyConfig:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_head: int = 32
    n_experts: int = 8
    top_k: int = 2
    d_ff: int = 256
    max_seq: int = 640  # prefill window + decode budget
    group: int = 32  # quant group (paper: G32 for experts)
    aux_coef: float = 0.01
    eps: float = 1e-6


CFG = TinyConfig()


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_params(cfg: TinyConfig, seed: int = 0) -> Params:
    k = jax.random.PRNGKey(seed)
    ks = iter(jax.random.split(k, 8 + cfg.n_layers * 10))
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts

    def dense(key, shape, scale=None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
        return jax.random.normal(key, shape, jnp.float32) * scale

    p: Params = {
        "embed": dense(next(ks), (cfg.vocab, d), 0.02),
        "pos": dense(next(ks), (cfg.max_seq, d), 0.02),
        "ln_f": jnp.ones((d,), jnp.float32),
        "w_out": dense(next(ks), (d, cfg.vocab)),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        lp = {
            "ln1": jnp.ones((d,), jnp.float32),
            "wq": dense(next(ks), (d, d)),
            "wk": dense(next(ks), (d, d)),
            "wv": dense(next(ks), (d, d)),
            "wo": dense(next(ks), (d, d)),
            "ln2": jnp.ones((d,), jnp.float32),
            "wg": dense(next(ks), (d, e)),
            "w1": dense(next(ks), (e, d, f)),
            "w3": dense(next(ks), (e, d, f)),
            "w2": dense(next(ks), (e, f, d)),
        }
        p["layers"].append(lp)
    return p


# ---------------------------------------------------------------------------
# Training face (pure jnp, dense routing)
# ---------------------------------------------------------------------------


def _mha(x, lp, cfg: TinyConfig, mask):
    """Multi-head attention over a full sequence. x: [S, d]."""
    s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    xn = ref.rmsnorm_ref(x, lp["ln1"], cfg.eps)
    q = (xn @ lp["wq"]).reshape(s, h, dh).transpose(1, 0, 2)
    k = (xn @ lp["wk"]).reshape(s, h, dh).transpose(1, 0, 2)
    v = (xn @ lp["wv"]).reshape(s, h, dh).transpose(1, 0, 2)
    att = jnp.einsum("hqd,hkd->hqk", q, k) / np.sqrt(dh)
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("hqk,hkd->hqd", att, v).transpose(1, 0, 2).reshape(s, d)
    return x + o @ lp["wo"]


def _moe_dense(x, lp, cfg: TinyConfig):
    """Dense differentiable MoE block. Returns (y, aux_loss, probs)."""
    xn = ref.rmsnorm_ref(x, lp["ln2"], cfg.eps)
    probs = jax.nn.softmax(xn @ lp["wg"], axis=-1)  # [S, E]
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    gates = topv / (topv.sum(axis=-1, keepdims=True) + 1e-9)  # renormalized
    # All-expert computation (tiny model: affordable, exactly differentiable)
    hs = jnp.einsum("sd,edf->sef", xn, lp["w1"])
    us = jnp.einsum("sd,edf->sef", xn, lp["w3"])
    ys = jnp.einsum("sef,efd->sed", jax.nn.silu(hs) * us, lp["w2"])  # [S,E,d]
    sel = jax.nn.one_hot(topi, cfg.n_experts)  # [S,K,E]
    w_full = jnp.einsum("ske,sk->se", sel, gates)  # [S, E]
    y = jnp.einsum("se,sed->sd", w_full, ys)
    # Switch aux loss: fraction routed * mean prob, per expert
    frac = sel.sum(axis=1).mean(axis=0)  # [E]
    mean_p = probs.mean(axis=0)
    aux = cfg.n_experts * jnp.sum(frac * mean_p)
    return x + y, aux, probs


def forward_dense(params: Params, tokens, cfg: TinyConfig = CFG, pos0=0):
    """tokens: int32 [S]; pos0: position offset (training uses random
    offsets so every row of the position table is trained — the serving
    path evaluates at arbitrary positions up to max_seq).
    Returns (logits [S, V], aux)."""
    s = tokens.shape[0]
    pe = jax.lax.dynamic_slice_in_dim(params["pos"], pos0, s, axis=0)
    x = params["embed"][tokens] + pe
    mask = jnp.tril(jnp.ones((s, s), bool))[None, :, :]
    aux_total = 0.0
    for lp in params["layers"]:
        x = _mha(x, lp, cfg, mask)
        x, aux, _ = _moe_dense(x, lp, cfg)
        aux_total = aux_total + aux
    xf = ref.rmsnorm_ref(x, params["ln_f"], cfg.eps)
    return xf @ params["w_out"], aux_total / cfg.n_layers


def loss_fn(params: Params, tokens, cfg: TinyConfig = CFG, pos0=None):
    """Next-byte cross-entropy + load-balance aux. tokens: [B, S+1];
    pos0: optional int32 [B] per-sequence position offsets."""
    if pos0 is None:
        pos0 = jnp.zeros((tokens.shape[0],), jnp.int32)

    def one(seq, p0):
        logits, aux = forward_dense(params, seq[:-1], cfg, p0)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, seq[1:, None], axis=-1).mean()
        return nll, aux

    nll, aux = jax.vmap(one)(tokens, pos0)
    return nll.mean() + cfg.aux_coef * aux.mean(), nll.mean()


# ---------------------------------------------------------------------------
# Serving face (per-op entry points, lowered to HLO by aot.py)
# ---------------------------------------------------------------------------


def embed_step(tokens, pos0, embed, pos):
    """tokens: i32[T]; pos0: i32[] start offset -> x f32[T, d]."""
    t = tokens.shape[0]
    pe = jax.lax.dynamic_slice_in_dim(pos, pos0, t, axis=0)
    return embed[tokens] + pe


def attn_prefill_step(x, valid_len, ln1, wq, wk, wv, wo, cfg: TinyConfig = CFG):
    """Full-sequence attention (residual included).

    x: [S, d] padded to cfg.max_seq; valid_len masks padding.
    Returns (h [S,d], k [H,S,dh], v [H,S,dh]) — the KV cache for decode.
    """
    s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    xn = ref.rmsnorm_ref(x, ln1, cfg.eps)
    q = (xn @ wq).reshape(s, h, dh).transpose(1, 0, 2)
    k = (xn @ wk).reshape(s, h, dh).transpose(1, 0, 2)
    v = (xn @ wv).reshape(s, h, dh).transpose(1, 0, 2)
    ar = jnp.arange(s)
    causal = ar[None, :] <= ar[:, None]
    valid = ar[None, :] < valid_len
    mask = (causal & valid)[None, :, :]
    att = jnp.einsum("hqd,hkd->hqk", q, k) / np.sqrt(dh)
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("hqk,hkd->hqd", att, v).transpose(1, 0, 2).reshape(s, d)
    return x + o @ wo, k, v


def attn_decode_step(x, k_cache, v_cache, pos, ln1, wq, wk, wv, wo, cfg: TinyConfig = CFG):
    """Single-token attention against the KV cache.

    x: [1, d]; k_cache/v_cache: [H, S, dh]; pos: i32[] index of this token.
    Returns (h [1,d], k_cache', v_cache').
    """
    d = x.shape[1]
    h, dh = cfg.n_heads, cfg.d_head
    xn = ref.rmsnorm_ref(x, ln1, cfg.eps)
    q = (xn @ wq).reshape(1, h, dh).transpose(1, 0, 2)  # [H,1,dh]
    kt = (xn @ wk).reshape(1, h, dh).transpose(1, 0, 2)  # [H,1,dh]
    vt = (xn @ wv).reshape(1, h, dh).transpose(1, 0, 2)
    k_cache = jax.lax.dynamic_update_slice(k_cache, kt, (0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, vt, (0, pos, 0))
    s = k_cache.shape[1]
    att = jnp.einsum("hqd,hkd->hqk", q, k_cache) / np.sqrt(dh)  # [H,1,S]
    mask = (jnp.arange(s) <= pos)[None, None, :]
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("hqk,hkd->hqd", att, v_cache).transpose(1, 0, 2).reshape(1, d)
    return x + o @ wo, k_cache, v_cache


def gate_step(x, ln2, wg):
    """(rmsnorm(x), router probs). Pallas kernel inside."""
    return kernels.gate_softmax(x, ln2, wg)


def expert_high_step(xn, m1, l1, s1, z1, m3, l3, s3, z3, m2, l2, s2, z2,
                     *, group: int, shift: int):
    return kernels.amat_ffn_high(xn, m1, l1, s1, z1, m3, l3, s3, z3,
                                 m2, l2, s2, z2, group=group, shift=shift)


def expert_low_step(xn, m1, s1, z1, m3, s3, z3, m2, s2, z2, *, group: int):
    return kernels.amat_ffn_low(xn, m1, s1, z1, m3, s3, z3, m2, s2, z2, group=group)


def expert_fp_step(xn, w1, w3, w2):
    return kernels.ffn_fp(xn, w1, w3, w2)


def logits_step(x, ln_f, w_out, cfg: TinyConfig = CFG):
    xf = ref.rmsnorm_ref(x, ln_f, cfg.eps)
    return xf @ w_out


# ---------------------------------------------------------------------------
# Serving-face composition (python-side mirror of the rust engine; used by
# tests to prove the per-op path reproduces forward_dense exactly)
# ---------------------------------------------------------------------------


def forward_serving_fp(params: Params, tokens, cfg: TinyConfig = CFG):
    """Compose the serving ops (fp experts) the way the rust engine does.

    Single-sequence teacher-forced pass: prefill-style attention + per-token
    top-k routing with renormalized gates, experts at fp32.
    """
    s = tokens.shape[0]
    x = embed_step(tokens, jnp.int32(0), params["embed"], params["pos"])
    for lp in params["layers"]:
        x, _, _ = attn_prefill_step(x, jnp.int32(s), lp["ln1"], lp["wq"],
                                    lp["wk"], lp["wv"], lp["wo"], cfg)
        xn, probs = gate_step(x, lp["ln2"], lp["wg"])
        topv, topi = jax.lax.top_k(probs, cfg.top_k)
        gates = topv / (topv.sum(axis=-1, keepdims=True) + 1e-9)
        y = jnp.zeros_like(x)
        for e in range(cfg.n_experts):
            ye = expert_fp_step(xn, lp["w1"][e], lp["w3"][e], lp["w2"][e])
            w_e = ((topi == e) * gates).sum(axis=-1)  # [S]
            y = y + w_e[:, None] * ye
        x = x + y
    return logits_step(x, params["ln_f"], params["w_out"], cfg)
