"""AMAT — Calibration-Free Asymmetric Matryoshka Quantization.

Reference implementation of the paper's quantization scheme (SliceMoE §4.2),
shared by the build path (aot.py packs expert weights with it) and the test
suite (kernel oracles, golden files for the Rust mirror in
``rust/src/quant/``).

Semantics
---------
Group-wise (G along the *input* dimension, paper uses G32 for experts)
asymmetric uint quantization:

    scale = (max - min) / (2^b - 1)
    zp    = clamp(round(-min / scale), 0, 2^b - 1)
    q     = clamp(round(w / scale) + zp, 0, 2^b - 1)
    w_hat = scale * (q - zp)

Matryoshka truncation to ``b_low`` (the paper's key equation):

    shift        = b_high - b_low
    q_low_trunc  = floor(q_high / 2^shift)        (= q_high >> shift)
    zp_low_trunc = floor(zp_high / 2^shift)       (= zp_high >> shift)
    scale_low    = scale_high * 2^shift

Bit-sliced storage: ``q_high = (msb << shift) | lsb`` where the MSB plane is
exactly the truncated low-bit tensor. MSB-only execution therefore *is* the
AMAT low-bit quantizer — no duplicate weight copies.

The symmetric variant (Table 1's "Sym" rows) uses signed symmetric
quantization (zp = 0, scale over max|w|); truncating its q values
arithmetic-shifts negatives toward -inf, producing the catastrophic bias the
paper reports (PPL ~ 1e6..1e10). We implement it to reproduce those rows.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "QuantParams",
    "quantize_asym",
    "dequantize_asym",
    "quantize_sym",
    "dequantize_sym",
    "truncate_amat",
    "truncate_naive_asym",
    "truncate_sym",
    "split_planes",
    "merge_planes",
    "pack_bits",
    "unpack_bits",
    "GROUP_SIZE_DEFAULT",
]

GROUP_SIZE_DEFAULT = 32


@dataclasses.dataclass
class QuantParams:
    """Quantized tensor + per-group metadata.

    ``q`` has the source shape ``(rows, cols)``; groups run along the FIRST
    axis (the matmul contraction axis for a ``x @ w`` weight),
    ``rows % group == 0``. ``scale``/``zp`` have shape
    ``(rows // group, cols)`` — matching the kernel/ref layout.
    """

    q: np.ndarray  # uint (asym) or int (sym) codes, int32 storage
    scale: np.ndarray  # f32
    zp: np.ndarray  # int32; all-zero for symmetric
    bits: int
    group: int
    symmetric: bool

    def nbytes_logical(self) -> int:
        """Packed size in bytes: codes at ``bits`` bits + fp16 scale
        (+ ``bits``-bit zp for asymmetric), matching the Rust weight store
        accounting."""
        n = self.q.size
        code_bits = n * self.bits
        ngroups = self.scale.size
        meta_bits = ngroups * 16 + (0 if self.symmetric else ngroups * self.bits)
        return (code_bits + meta_bits + 7) // 8


def _group_view(w: np.ndarray, group: int) -> np.ndarray:
    """(rows, cols) -> (rows//group, group, cols); reductions run on axis 1."""
    rows, cols = w.shape
    if rows % group != 0:
        raise ValueError(f"rows={rows} not divisible by group={group}")
    return w.reshape(rows // group, group, cols)


def quantize_asym(w: np.ndarray, bits: int, group: int = GROUP_SIZE_DEFAULT) -> QuantParams:
    """Asymmetric per-group uint quantization (paper's expert scheme)."""
    w = np.asarray(w, dtype=np.float64)
    g = _group_view(w, group)
    lo = g.min(axis=1)
    hi = g.max(axis=1)
    qmax = float(2**bits - 1)
    scale = (hi - lo) / qmax
    # Degenerate (constant c) groups: scale=|c| makes the general formula
    # exact (q-zp = sign(c)); scale=1 when the group is all zero.
    degenerate = np.where(np.abs(lo) > 0.0, np.abs(lo), 1.0)
    scale = np.where(scale <= 0.0, degenerate, scale)
    zp = np.clip(np.round(-lo / scale), 0, qmax).astype(np.int64)
    q = np.round(g / scale[:, None, :]) + zp[:, None, :]
    q = np.clip(q, 0, qmax).astype(np.int64)
    return QuantParams(
        q=q.reshape(w.shape).astype(np.int32),
        scale=scale.astype(np.float32),
        zp=zp.astype(np.int32),
        bits=bits,
        group=group,
        symmetric=False,
    )


def dequantize_asym(p: QuantParams) -> np.ndarray:
    g = _group_view(p.q.astype(np.float32), p.group)
    w = p.scale[:, None, :] * (g - p.zp[:, None, :].astype(np.float32))
    return w.reshape(p.q.shape).astype(np.float32)


def quantize_sym(w: np.ndarray, bits: int, group: int = GROUP_SIZE_DEFAULT) -> QuantParams:
    """Signed symmetric per-group quantization (Table 1 "Sym" rows)."""
    w = np.asarray(w, dtype=np.float64)
    g = _group_view(w, group)
    amax = np.abs(g).max(axis=1)
    qmax = float(2 ** (bits - 1) - 1)
    scale = amax / qmax
    scale = np.where(scale <= 0.0, 1.0, scale)
    q = np.clip(np.round(g / scale[:, None, :]), -(qmax + 1), qmax).astype(np.int64)
    return QuantParams(
        q=q.reshape(w.shape).astype(np.int32),
        scale=scale.astype(np.float32),
        zp=np.zeros_like(scale, dtype=np.int32),
        bits=bits,
        group=group,
        symmetric=True,
    )


def dequantize_sym(p: QuantParams) -> np.ndarray:
    g = _group_view(p.q.astype(np.float32), p.group)
    w = p.scale[:, None, :] * g
    return w.reshape(p.q.shape).astype(np.float32)


def truncate_amat(p: QuantParams, b_low: int) -> QuantParams:
    """AMAT truncation: jointly shift codes AND zero-points (paper eq. §4.2)."""
    if p.symmetric:
        raise ValueError("AMAT truncation is defined for the asymmetric scheme")
    if b_low >= p.bits:
        raise ValueError(f"b_low={b_low} must be < bits={p.bits}")
    shift = p.bits - b_low
    return QuantParams(
        q=(p.q >> shift).astype(np.int32),
        scale=(p.scale * float(2**shift)).astype(np.float32),
        zp=(p.zp >> shift).astype(np.int32),
        bits=b_low,
        group=p.group,
        symmetric=False,
    )


def truncate_naive_asym(p: QuantParams, b_low: int) -> QuantParams:
    """Naive truncation baseline (Table 1 "Trunc"/Asym): RANGE truncation —
    codes clamp to the low-bit range while scale and zero-point stay at
    their high-bit values. The zero-point usually exceeds the clamped range
    entirely, destroying the dequant reference point (the ~1e9/nan rows)."""
    if p.symmetric:
        raise ValueError("use truncate_sym for the symmetric scheme")
    qmax = (1 << b_low) - 1
    return QuantParams(
        q=np.clip(p.q, 0, qmax).astype(np.int32),
        scale=p.scale.copy(),  # neither scale nor zp adjusted
        zp=p.zp.copy(),
        bits=b_low,
        group=p.group,
        symmetric=False,
    )


def truncate_sym(p: QuantParams, b_low: int) -> QuantParams:
    """Symmetric truncation baseline (Table 1 "Trunc"/Sym): RANGE truncation
    — signed codes clamp to the low-bit range at the ORIGINAL scale. Every
    weight beyond the shrunken range collapses to the boundary ("many
    values collapse to the truncated boundaries") — catastrophic clipping."""
    if not p.symmetric:
        raise ValueError("use truncate_amat/truncate_naive_asym for asym")
    qmax = (1 << (b_low - 1)) - 1
    return QuantParams(
        q=np.clip(p.q, -qmax - 1, qmax).astype(np.int32),
        scale=p.scale.copy(),
        zp=p.zp.copy(),
        bits=b_low,
        group=p.group,
        symmetric=True,
    )


def split_planes(p: QuantParams, b_low: int) -> tuple[np.ndarray, np.ndarray]:
    """Split high-bit codes into (msb, lsb) planes.

    ``msb`` is the b_low-bit plane (== truncate_amat(p, b_low).q) and ``lsb``
    holds the residual ``shift`` bits: ``q == (msb << shift) | lsb``.
    """
    shift = p.bits - b_low
    msb = (p.q >> shift).astype(np.int32)
    lsb = (p.q & ((1 << shift) - 1)).astype(np.int32)
    return msb, lsb


def merge_planes(msb: np.ndarray, lsb: np.ndarray, shift: int) -> np.ndarray:
    return ((msb.astype(np.int64) << shift) | lsb.astype(np.int64)).astype(np.int32)


def pack_bits(codes: np.ndarray, bits: int) -> np.ndarray:
    """Tightly pack non-negative integer codes (< 2^bits) into a u8 stream,
    little-endian bit order. Mirrors rust `quant::packing::pack_bits`."""
    flat = codes.reshape(-1).astype(np.uint64)
    if bits < 1 or bits > 16:
        raise ValueError("bits must be in 1..=16")
    if np.any(flat >= (1 << bits)):
        raise ValueError("code out of range for bits")
    n = flat.size
    total_bits = n * bits
    out = np.zeros((total_bits + 7) // 8, dtype=np.uint8)
    # Vectorized bit scatter: for each of `bits` bit positions, place bit j
    # of code i at stream position i*bits + j.
    for j in range(bits):
        bit = ((flat >> j) & 1).astype(np.uint8)
        pos = np.arange(n, dtype=np.int64) * bits + j
        np.bitwise_or.at(out, pos >> 3, (bit << (pos & 7)).astype(np.uint8))
    return out


def unpack_bits(packed: np.ndarray, bits: int, count: int) -> np.ndarray:
    """Inverse of pack_bits -> int32 array of length ``count``."""
    packed = np.asarray(packed, dtype=np.uint8)
    out = np.zeros(count, dtype=np.int64)
    for j in range(bits):
        pos = np.arange(count, dtype=np.int64) * bits + j
        bit = (packed[pos >> 3] >> (pos & 7)) & 1
        out |= bit.astype(np.int64) << j
    return out.astype(np.int32)
