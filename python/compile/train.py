"""Build-time trainer for the tiny MoE byte-LM.

Runs ONCE from `make artifacts` (skipped when weights.npz already exists).
Adam + cosine schedule, Switch-style load-balance aux (see model.py).
CPU-only, a few minutes. Saves a flat .npz checkpoint that aot.py and the
test-suite consume.

Usage: python -m compile.train --out ../artifacts/weights.npz --steps 400
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import CFG, TinyConfig, init_params, loss_fn


def batches(data: np.ndarray, batch: int, seqlen: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(data) - seqlen - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        yield np.stack([data[i : i + seqlen + 1] for i in idx]).astype(np.int32)


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return z, jax.tree.map(jnp.zeros_like, params)


def train(cfg: TinyConfig = CFG, steps: int = 400, batch: int = 8,
          seqlen: int = 192, lr: float = 3e-3, seed: int = 0,
          log_every: int = 25) -> tuple[dict, list[tuple[int, float]]]:
    params = init_params(cfg, seed)
    m, v = adam_init(params)
    b1, b2, eps = 0.9, 0.95, 1e-8

    @jax.jit
    def step_fn(params, m, v, tokens, pos0, step):
        (loss, nll), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, tokens, cfg, pos0)
        t = step + 1
        frac = jnp.minimum(t / steps, 1.0)
        lr_t = lr * 0.5 * (1 + jnp.cos(jnp.pi * frac)) + 1e-5

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1**t)
            vh = v / (1 - b2**t)
            return p - lr_t * mh / (jnp.sqrt(vh) + eps), m, v

        out = jax.tree.map(upd, params, grads, m, v)
        params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return params, m, v, loss, nll

    train_data, _ = corpus.train_eval_split()
    data = np.frombuffer(train_data, dtype=np.uint8)
    it = batches(data, batch, seqlen, seed + 1)
    pos_rng = np.random.default_rng(seed + 2)
    log: list[tuple[int, float]] = []
    t0 = time.time()
    for s in range(steps):
        tok = next(it)
        # random position offsets: every row of the position table trains
        pos0 = pos_rng.integers(0, cfg.max_seq - seqlen, size=batch).astype(np.int32)
        params, m, v, loss, nll = step_fn(params, m, v, tok, pos0, s)
        if s % log_every == 0 or s == steps - 1:
            nll_f = float(nll)
            log.append((s, nll_f))
            print(f"step {s:4d}  nll/byte {nll_f:.4f}  ppl {np.exp(nll_f):8.3f}  "
                  f"({time.time()-t0:5.1f}s)", flush=True)
    return params, log


def flatten_params(params) -> dict[str, np.ndarray]:
    out = {
        "embed": params["embed"], "pos": params["pos"],
        "ln_f": params["ln_f"], "w_out": params["w_out"],
    }
    for i, lp in enumerate(params["layers"]):
        for k, val in lp.items():
            out[f"layer{i}.{k}"] = val
    return {k: np.asarray(v) for k, v in out.items()}


def unflatten_params(flat: dict, cfg: TinyConfig = CFG) -> dict:
    p = {"embed": jnp.asarray(flat["embed"]), "pos": jnp.asarray(flat["pos"]),
         "ln_f": jnp.asarray(flat["ln_f"]), "w_out": jnp.asarray(flat["w_out"]),
         "layers": []}
    for i in range(cfg.n_layers):
        p["layers"].append({k: jnp.asarray(flat[f"layer{i}.{k}"])
                            for k in ["ln1", "wq", "wk", "wv", "wo",
                                      "ln2", "wg", "w1", "w3", "w2"]})
    return p


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/weights.npz")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seqlen", type=int, default=192)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    params, log = train(steps=args.steps, batch=args.batch,
                        seqlen=args.seqlen, seed=args.seed)
    flat = flatten_params(params)
    flat["_train_log_steps"] = np.array([s for s, _ in log], np.int32)
    flat["_train_log_nll"] = np.array([l for _, l in log], np.float32)
    np.savez(args.out, **flat)
    print(f"saved {args.out}")


if __name__ == "__main__":
    main()
