"""AOT compile path: lower every serving entry point to HLO **text** and
dump weight/corpus blobs for the Rust coordinator.

HLO text (NOT ``lowered.compiler_ir().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Outputs (artifacts/):
  *.hlo.txt          one per entry point (see MANIFEST below)
  weights.bin        every trained tensor, SMWB container (see _write_blob)
  golden_quant.bin   python-side AMAT results for rust cross-validation
  corpus_eval.bin / corpus_train.bin
  model_meta.json    geometry + artifact manifest + train log

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, quant
from .model import (
    CFG,
    attn_decode_step,
    attn_prefill_step,
    embed_step,
    expert_fp_step,
    expert_high_step,
    expert_low_step,
    gate_step,
    logits_step,
)
from .train import unflatten_params

F32 = jnp.float32
I32 = jnp.int32

# MAT(h,l) bit configurations swept by the paper (Table 1); shift = h - l.
MAT_SHIFTS = (2, 3, 4)  # MAT42, MAT63, MAT84


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entry_points(cfg=CFG):
    """entry name -> (fn, example arg specs). T axis: S=prefill, 1=decode."""
    d, f, e, g = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.group
    s, h, dh, v = cfg.max_seq, cfg.n_heads, cfg.d_head, cfg.vocab

    def expert_quant_specs(t, with_lsb):
        """Arg specs for one expert call: w1, w3 ([d,f]), then w2 ([f,d])."""
        out = [spec((t, d))]
        for din, dout in ((d, f), (d, f), (f, d)):
            out.append(spec((din, dout), I32))  # msb
            if with_lsb:
                out.append(spec((din, dout), I32))  # lsb
            out.append(spec((din // g, dout)))  # scale
            out.append(spec((din // g, dout), I32))  # zp
        return out

    entries = {}
    for tag, t in (("prefill", s), ("decode", 1)):
        entries[f"embed_{tag}"] = (
            lambda tok, p0, emb, pos: (embed_step(tok, p0, emb, pos),),
            [spec((t,), I32), spec((), I32), spec((v, d)), spec((s, d))],
        )
        entries[f"gate_{tag}"] = (
            gate_step,
            [spec((t, d)), spec((d,)), spec((d, e))],
        )
        entries[f"logits_{tag}"] = (
            lambda x, lnf, wout: (logits_step(x, lnf, wout),),
            [spec((t, d)), spec((d,)), spec((d, v))],
        )
        entries[f"expert_fp_{tag}"] = (
            lambda xn, w1, w3, w2: (expert_fp_step(xn, w1, w3, w2),),
            [spec((t, d)), spec((d, f)), spec((d, f)), spec((f, d))],
        )
        entries[f"expert_low_{tag}"] = (
            lambda xn, *a: (expert_low_step(xn, *a, group=g),),
            expert_quant_specs(t, with_lsb=False),
        )
        for shift in MAT_SHIFTS:
            entries[f"expert_high_s{shift}_{tag}"] = (
                functools.partial(
                    lambda shift_, xn, *a: (
                        expert_high_step(xn, *a, group=g, shift=shift_),
                    ),
                    shift,
                ),
                expert_quant_specs(t, with_lsb=True),
            )

    entries["attn_prefill"] = (
        attn_prefill_step,
        [spec((s, d)), spec((), I32)] + [spec(sh) for sh in
                                         [(d,), (d, d), (d, d), (d, d), (d, d)]],
    )
    entries["attn_decode"] = (
        attn_decode_step,
        [spec((1, d)), spec((h, s, dh)), spec((h, s, dh)), spec((), I32)]
        + [spec(sh) for sh in [(d,), (d, d), (d, d), (d, d), (d, d)]],
    )
    return entries


# ---------------------------------------------------------------------------
# SMWB tensor container (mirrored by rust/src/model/blob.rs)
# ---------------------------------------------------------------------------

_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}


def _write_blob(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as fh:
        fh.write(b"SMWB0001")
        fh.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.float64:
                arr = arr.astype(np.float32)
            if arr.dtype == np.int64:
                arr = arr.astype(np.int32)
            code = _DTYPES[arr.dtype]
            nb = name.encode()
            fh.write(struct.pack("<H", len(nb)))
            fh.write(nb)
            fh.write(struct.pack("<BB", code, arr.ndim))
            for dim in arr.shape:
                fh.write(struct.pack("<I", dim))
            raw = arr.tobytes()
            fh.write(struct.pack("<Q", len(raw)))
            fh.write(raw)


def golden_quant_tensors(flat: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Python-side AMAT results on a real trained weight, for the rust
    cross-check (rust re-derives all of these from weights.bin)."""
    w = np.asarray(flat["layer0.w1"][0])  # [d, f] trained expert weight
    out: dict[str, np.ndarray] = {"src": w.astype(np.float32)}
    for bh, bl in ((4, 2), (6, 3), (8, 4)):
        q = quant.quantize_asym(w, bh, CFG.group)
        msb, lsb = quant.split_planes(q, bl)
        am = quant.truncate_amat(q, bl)
        sym = quant.quantize_sym(w, bh, CFG.group)
        symt = quant.truncate_sym(sym, bl)
        tag = f"mat{bh}{bl}"
        out[f"{tag}.q"] = q.q
        out[f"{tag}.scale"] = q.scale
        out[f"{tag}.zp"] = q.zp
        out[f"{tag}.msb"] = msb
        out[f"{tag}.lsb"] = lsb
        out[f"{tag}.amat_scale"] = am.scale
        out[f"{tag}.amat_zp"] = am.zp
        out[f"{tag}.packed_msb"] = quant.pack_bits(msb, bl)
        out[f"{tag}.sym_q"] = sym.q
        out[f"{tag}.sym_scale"] = sym.scale
        out[f"{tag}.symt_q"] = symt.q
        out[f"{tag}.dequant"] = quant.dequantize_asym(q)
        out[f"{tag}.dequant_low"] = quant.dequantize_asym(am)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--weights", default=None, help="default: <out-dir>/weights.npz")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    wpath = args.weights or os.path.join(out, "weights.npz")
    flat = dict(np.load(wpath))

    # 1. HLO artifacts
    manifest = {}
    for name, (fn, specs) in build_entry_points().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out, fname), "w") as fh:
            fh.write(text)
        manifest[name] = {
            "file": fname,
            "args": [[list(s.shape), str(s.dtype)] for s in specs],
        }
        print(f"lowered {name:28s} {len(text):9d} chars")

    # 2. Weight blob (fp32 master — rust quantizes per configuration)
    tensors = {k: v for k, v in flat.items() if not k.startswith("_")}
    _write_blob(os.path.join(out, "weights.bin"), tensors)

    # 3. Golden quant cross-check blob
    _write_blob(os.path.join(out, "golden_quant.bin"), golden_quant_tensors(flat))

    # 4. Corpus
    train_b, eval_b = corpus.train_eval_split()
    with open(os.path.join(out, "corpus_train.bin"), "wb") as fh:
        fh.write(train_b[: 1 << 18])
    with open(os.path.join(out, "corpus_eval.bin"), "wb") as fh:
        fh.write(eval_b)

    # 5. Meta
    meta = {
        "model": "tiny-moe-bytelm",
        "config": {
            "vocab": CFG.vocab, "d_model": CFG.d_model,
            "n_layers": CFG.n_layers, "n_heads": CFG.n_heads,
            "d_head": CFG.d_head, "n_experts": CFG.n_experts,
            "top_k": CFG.top_k, "d_ff": CFG.d_ff,
            "max_seq": CFG.max_seq, "group": CFG.group,
        },
        "mat_shifts": list(MAT_SHIFTS),
        "artifacts": manifest,
        "train_log": {
            "steps": [int(x) for x in flat.get("_train_log_steps", [])],
            "nll": [float(x) for x in flat.get("_train_log_nll", [])],
        },
    }
    with open(os.path.join(out, "model_meta.json"), "w") as fh:
        json.dump(meta, fh, indent=1)
    print(f"wrote weights.bin golden_quant.bin corpus_*.bin model_meta.json -> {out}")


if __name__ == "__main__":
    main()
