"""L1 — Pallas kernels for the SliceMoE compute hot-spot.

The hot-spot is the *bit-sliced expert FFN*: dequantize AMAT group-quantized
weights from their bit-planes and run the SwiGLU expert matmuls. Two
variants exist so the low-precision path never touches LSB memory (the
whole point of DBSC — an expert whose LSB slice missed must be computable
from the MSB plane alone):

* ``amat_ffn_high``  — operands: MSB **and** LSB planes + high-bit group
  params. In-kernel: ``q = (msb << shift) | lsb``, dequant, SwiGLU.
* ``amat_ffn_low``   — operands: MSB planes + AMAT-truncated group params
  (``scale << shift``, ``zp >> shift`` — computed by the caller/weight
  store). In-kernel: dequant the b_low codes directly, SwiGLU.
* ``ffn_fp``         — fp32 reference expert (Base configs, Table 1).
* ``gate_softmax``   — router gate: rmsnorm → x@Wg → softmax (returns both
  the normed activations and the probabilities; the rust coordinator feeds
  the normed rows back into the expert kernels).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks d_ff tiles;
each step holds one (din × BF) slice of w1/w3 and one (BF × dout) slice of
w2 in VMEM, dequantizes on the VPU and feeds the MXU matmuls, accumulating
into the output block. The paper's NPU streams experts through a systolic
array the same way. ``interpret=True`` everywhere — CPU PJRT cannot run
Mosaic custom-calls; numerics are validated against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "amat_ffn_high",
    "amat_ffn_low",
    "ffn_fp",
    "gate_softmax",
    "DEFAULT_BLOCK_F",
]

# d_ff tile width. Must divide d_ff and be a multiple of the quant group so
# scale/zp tiles stay aligned. 128 matches the MXU lane dimension.
DEFAULT_BLOCK_F = 128


def _dequant_block(q, scale, zp, group: int):
    """w = scale * (q - zp) with per-group params expanded over the group.

    q: [din, bf] int32; scale: [din//group, bf] f32; zp: [din//group, bf].
    """
    din, bf = q.shape
    s = jnp.repeat(scale, group, axis=0)
    z = jnp.repeat(zp, group, axis=0)
    return s * (q - z).astype(jnp.float32)


def _ffn_kernel(
    x_ref,
    m1_ref, l1_ref, s1_ref, z1_ref,
    m3_ref, l3_ref, s3_ref, z3_ref,
    m2_ref, l2_ref, s2_ref, z2_ref,
    o_ref,
    *, group: int, shift: int, with_lsb: bool,
):
    """One d_ff tile: partial h = silu(x@w1)*(x@w3); o += h@w2."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def load(m_ref, l_ref, s_ref, z_ref):
        q = m_ref[...]
        if with_lsb:
            q = (q << shift) | l_ref[...]
        return _dequant_block(q, s_ref[...], z_ref[...], group)

    x = x_ref[...]
    w1 = load(m1_ref, l1_ref, s1_ref, z1_ref)
    w3 = load(m3_ref, l3_ref, s3_ref, z3_ref)
    w2 = load(m2_ref, l2_ref, s2_ref, z2_ref)
    h = jax.nn.silu(x @ w1) * (x @ w3)
    o_ref[...] += h @ w2


def _ffn_call(x, ops, *, group: int, shift: int, with_lsb: bool, block_f: int):
    """Shared pallas_call wiring for the high/low variants.

    ops = (m1, l1, s1, z1, m3, l3, s3, z3, m2, l2, s2, z2); the l* planes
    are ignored (still passed, all-zero) when with_lsb=False so both
    variants share one kernel body — the *compiled* low artifact simply has
    no LSB operands (see ``amat_ffn_low``).
    """
    t, din = x.shape
    dout = ops[8].shape[1]
    d_ff = ops[0].shape[1]
    if d_ff % block_f:
        raise ValueError(f"d_ff={d_ff} not divisible by block_f={block_f}")
    if block_f % group:
        raise ValueError(f"block_f={block_f} not a multiple of group={group}")
    grid = (d_ff // block_f,)
    gf = block_f // group

    full = lambda *shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    col_tile = lambda rows: pl.BlockSpec((rows, block_f), lambda i: (0, i))
    colmeta_tile = lambda rows: pl.BlockSpec((rows, block_f), lambda i: (0, i))
    row_tile = pl.BlockSpec((block_f, dout), lambda i: (i, 0))
    rowmeta_tile = pl.BlockSpec((gf, dout), lambda i: (i, 0))

    gdin = din // group
    in_specs = [
        full(t, din),
        # w1: [din, d_ff] planes, groups along din
        col_tile(din), col_tile(din), colmeta_tile(gdin), colmeta_tile(gdin),
        # w3: same layout as w1
        col_tile(din), col_tile(din), colmeta_tile(gdin), colmeta_tile(gdin),
        # w2: [d_ff, dout] planes, groups along d_ff
        row_tile, row_tile, rowmeta_tile, rowmeta_tile,
    ]
    kernel = functools.partial(_ffn_kernel, group=group, shift=shift, with_lsb=with_lsb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=full(t, dout),
        out_shape=jax.ShapeDtypeStruct((t, dout), jnp.float32),
        interpret=True,
    )(x, *ops)


def amat_ffn_high(
    x,
    m1, l1, s1, z1,
    m3, l3, s3, z3,
    m2, l2, s2, z2,
    *, group: int, shift: int, block_f: int = DEFAULT_BLOCK_F,
):
    """Critical-expert path: both slices cached → full b_high precision."""
    ops = (m1, l1, s1, z1, m3, l3, s3, z3, m2, l2, s2, z2)
    return _ffn_call(x, ops, group=group, shift=shift, with_lsb=True, block_f=block_f)


def amat_ffn_low(
    x,
    m1, s1, z1,
    m3, s3, z3,
    m2, s2, z2,
    *, group: int, block_f: int = DEFAULT_BLOCK_F,
):
    """Non-critical / LSB-miss path: MSB plane only.

    Callers pass AMAT-truncated params (scale<<shift, zp>>shift). The same
    entry also serves Table 1's symmetric and naive-truncation baselines:
    signed codes with zp=0 reproduce symmetric dequant, and unshifted
    scale/zp reproduce the naive truncation.
    """
    zero = lambda m: jnp.zeros_like(m)
    ops = (m1, zero(m1), s1, z1, m3, zero(m3), s3, z3, m2, zero(m2), s2, z2)
    return _ffn_call(x, ops, group=group, shift=0, with_lsb=False, block_f=block_f)


def _ffn_fp_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    h = jax.nn.silu(x @ w1_ref[...]) * (x @ w3_ref[...])
    o_ref[...] += h @ w2_ref[...]


def ffn_fp(x, w1, w3, w2, *, block_f: int = DEFAULT_BLOCK_F):
    """fp32 SwiGLU expert (Base / reference configurations)."""
    t, din = x.shape
    d_ff, dout = w2.shape
    grid = (d_ff // block_f,)
    return pl.pallas_call(
        _ffn_fp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, din), lambda i: (0, 0)),
            pl.BlockSpec((din, block_f), lambda i: (0, i)),
            pl.BlockSpec((din, block_f), lambda i: (0, i)),
            pl.BlockSpec((block_f, dout), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((t, dout), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, dout), jnp.float32),
        interpret=True,
    )(x, w1, w3, w2)


def _gate_kernel(x_ref, g_ref, wg_ref, xn_ref, p_ref, *, eps: float):
    x = x_ref[...]
    v = jnp.mean(x * x, axis=-1, keepdims=True)
    xn = x * jax.lax.rsqrt(v + eps) * g_ref[...]
    logits = xn @ wg_ref[...]
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    xn_ref[...] = xn
    p_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def gate_softmax(x, ln_w, wg, *, eps: float = 1e-6):
    """Router gate: (rmsnorm(x), softmax(rmsnorm(x) @ wg)).

    Single-block kernel — the gate matmul is tiny ([T,d]×[d,E]) and lives
    entirely in VMEM.
    """
    t, d = x.shape
    e = wg.shape[1]
    return pl.pallas_call(
        functools.partial(_gate_kernel, eps=eps),
        out_shape=(
            jax.ShapeDtypeStruct((t, d), jnp.float32),
            jax.ShapeDtypeStruct((t, e), jnp.float32),
        ),
        interpret=True,
    )(x, ln_w, wg)
