"""Pure-jnp oracles for the Pallas kernels.

Every kernel in ``amat_ffn.py`` has a reference here written with plain
``jax.numpy`` ops only — no pallas, no custom calls. pytest asserts
allclose between kernel and oracle across shape/dtype sweeps; this is the
core L1 correctness signal.
"""

from __future__ import annotations

import jax.nn
import jax.numpy as jnp


def dequant_asym_ref(q, scale, zp, group: int):
    """Dequantize group-quantized codes.

    q: int32 [din, dout]; scale: f32 [din//group, dout];
    zp: int32 [din//group, dout]. Groups run along the *input* (contraction)
    dimension — matching the paper's G32-along-input expert layout.
    """
    din, dout = q.shape
    qg = q.reshape(din // group, group, dout).astype(jnp.float32)
    w = scale[:, None, :] * (qg - zp[:, None, :].astype(jnp.float32))
    return w.reshape(din, dout)


def merge_planes_ref(msb, lsb, shift: int):
    """q_high = (msb << shift) | lsb."""
    return (msb.astype(jnp.int32) << shift) | lsb.astype(jnp.int32)


def swiglu_ref(x, w1, w3, w2):
    """SwiGLU expert FFN: (silu(x @ w1) * (x @ w3)) @ w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def amat_ffn_high_ref(x, planes, scales, zps, group: int, shift: int):
    """Full-precision expert: merge MSB|LSB planes, dequant at b_high.

    planes: tuple of 3 (msb, lsb) pairs for w1, w3, w2.
    scales/zps: tuples of 3 high-bit group params.
    """
    ws = []
    for (msb, lsb), s, z in zip(planes, scales, zps):
        q = merge_planes_ref(msb, lsb, shift)
        ws.append(dequant_asym_ref(q, s, z, group))
    return swiglu_ref(x, *ws)


def amat_ffn_low_ref(x, msbs, scales_low, zps_low, group: int):
    """Low-precision expert: MSB plane only with AMAT-truncated params."""
    ws = [dequant_asym_ref(m, s, z, group) for m, s, z in zip(msbs, scales_low, zps_low)]
    return swiglu_ref(x, *ws)


def gate_ref(x, wg):
    """Router gate: softmax(x @ wg) over the expert axis."""
    return jax.nn.softmax(x @ wg, axis=-1)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    v = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(v + eps) * w
