"""Deterministic synthetic corpus for the tiny byte-LM.

The paper evaluates on GSM8K because it produces *long decodes* (prefill
~500 tokens, decode >100). We cannot ship GSM8K, so the corpus is a
synthetic pseudo-language with enough structure that (a) a 3.6 M-param MoE
actually learns non-trivial statistics (PPL well below uniform-256), and
(b) quantization damage is measurable: templated sentences, a closed
vocabulary with Zipfian word frequencies, and small arithmetic facts whose
digits force precise logits.

Everything is seeded — `make artifacts` is reproducible bit-for-bit.
"""

from __future__ import annotations

import random

WORDS = [
    # Zipf-ish ranked vocabulary (rank ~ frequency via the sampler below)
    "the", "a", "cache", "expert", "slice", "token", "model", "route",
    "score", "layer", "memory", "flash", "dram", "miss", "hit", "bit",
    "plane", "gate", "warm", "cold", "fetch", "evict", "load", "store",
    "high", "low", "fast", "slow", "small", "large", "dense", "sparse",
    "quant", "scale", "zero", "point", "shift", "merge", "split", "pack",
]

TEMPLATES = [
    "{w1} {w2} routes to {w3} {w4}.",
    "if {w1} misses then {w2} fetches the {w3}.",
    "the {w1} holds {n1} {w2}s and {n2} {w3}s.",
    "{n1} plus {n2} equals {sum}.",
    "{n1} times two equals {dbl}.",
    "expert {n1} keeps its {w1} slice in {w2}.",
    "when the {w1} is {w2} the {w3} stays {w4}.",
    "{w1} precision needs {n1} bits per {w2}.",
]


def _word(rng: random.Random) -> str:
    # Zipf sampling: rank r with p ~ 1/(r+2)
    weights = [1.0 / (i + 2) for i in range(len(WORDS))]
    return rng.choices(WORDS, weights=weights, k=1)[0]


def _sentence(rng: random.Random) -> str:
    t = rng.choice(TEMPLATES)
    n1, n2 = rng.randint(1, 49), rng.randint(1, 49)
    return t.format(
        w1=_word(rng), w2=_word(rng), w3=_word(rng), w4=_word(rng),
        n1=n1, n2=n2, sum=n1 + n2, dbl=n1 * 2,
    )


def generate(n_bytes: int, seed: int = 1234) -> bytes:
    rng = random.Random(seed)
    parts: list[str] = []
    size = 0
    while size < n_bytes:
        s = _sentence(rng) + " "
        parts.append(s)
        size += len(s)
    return "".join(parts).encode("ascii")[:n_bytes]


def train_eval_split(train_bytes: int = 1 << 21, eval_bytes: int = 1 << 16,
                     seed: int = 1234) -> tuple[bytes, bytes]:
    """Disjoint train/eval streams (different seeds => different sentences)."""
    return generate(train_bytes, seed), generate(eval_bytes, seed + 7919)
