"""AOT path checks: every entry point lowers to parseable HLO text, the
manifest matches the entry registry, and the SMWB blob container
round-trips.
"""

import os
import struct

import numpy as np
import pytest

from compile import aot
from compile.model import CFG


def test_entry_registry_complete():
    entries = aot.build_entry_points()
    # 2 phases x {embed, gate, logits, expert_fp, expert_low} + high per shift
    expected = {f"{n}_{t}" for t in ("prefill", "decode")
                for n in ("embed", "gate", "logits", "expert_fp", "expert_low")}
    expected |= {f"expert_high_s{s}_{t}" for s in aot.MAT_SHIFTS
                 for t in ("prefill", "decode")}
    expected |= {"attn_prefill", "attn_decode"}
    assert set(entries) == expected


@pytest.mark.parametrize("name", ["gate_decode", "logits_decode", "embed_decode"])
def test_small_entry_lowers_to_hlo_text(name):
    import jax

    fn, specs = aot.build_entry_points()[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "HloModule" in text
    # must not contain mosaic custom-calls (interpret=True everywhere)
    assert "tpu_custom_call" not in text


def test_expert_low_decode_lowers():
    import jax

    fn, specs = aot.build_entry_points()["expert_low_decode"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text


def test_blob_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "t.bin")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.c": np.arange(5, dtype=np.int32),
        "packed": np.frombuffer(b"\x01\x02\xff", dtype=np.uint8),
    }
    aot._write_blob(path, tensors)
    with open(path, "rb") as fh:
        assert fh.read(8) == b"SMWB0001"
        (count,) = struct.unpack("<I", fh.read(4))
        assert count == 3
        got = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<H", fh.read(2))
            name = fh.read(nlen).decode()
            code, ndim = struct.unpack("<BB", fh.read(2))
            dims = struct.unpack(f"<{ndim}I", fh.read(4 * ndim))
            (nbytes,) = struct.unpack("<Q", fh.read(8))
            raw = fh.read(nbytes)
            dt = {0: np.float32, 1: np.int32, 2: np.uint8}[code]
            got[name] = np.frombuffer(raw, dt).reshape(dims)
    for k, v in tensors.items():
        np.testing.assert_array_equal(got[k], v)


def test_golden_quant_tensors_shapes():
    rng = np.random.default_rng(0)
    flat = {"layer0.w1": rng.standard_normal(
        (CFG.n_experts, CFG.d_model, CFG.d_ff)).astype(np.float32)}
    g = aot.golden_quant_tensors(flat)
    for tag in ("mat42", "mat63", "mat84"):
        assert g[f"{tag}.q"].shape == (CFG.d_model, CFG.d_ff)
        assert g[f"{tag}.scale"].shape == (CFG.d_model // CFG.group, CFG.d_ff)
        # msb is exactly the AMAT-truncated code plane
        bh = int(tag[3]); bl = int(tag[4])
        assert (g[f"{tag}.msb"] == g[f"{tag}.q"] >> (bh - bl)).all()


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/model_meta.json")),
    reason="artifacts not built")
def test_built_artifacts_manifest_consistent():
    import json

    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    meta = json.load(open(os.path.join(root, "model_meta.json")))
    for name, ent in meta["artifacts"].items():
        p = os.path.join(root, ent["file"])
        assert os.path.exists(p), name
        head = open(p).read(4096)
        assert "HloModule" in head
