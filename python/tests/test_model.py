"""L2 model checks: serving-face ops compose to the training-face forward,
attention decode is consistent with prefill, and shapes are as the AOT
manifest declares.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    CFG,
    TinyConfig,
    attn_decode_step,
    attn_prefill_step,
    embed_step,
    forward_dense,
    forward_serving_fp,
    gate_step,
    init_params,
    logits_step,
    loss_fn,
)

SMALL = TinyConfig(d_model=64, n_layers=2, n_heads=2, d_head=32,
                   n_experts=4, top_k=2, d_ff=128, max_seq=64)


@pytest.fixture(scope="module")
def params():
    return init_params(SMALL, seed=1)


def toks(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.array(rng.integers(0, 256, n), jnp.int32)


def test_serving_composition_matches_dense_forward(params):
    """Per-op serving path (pallas experts) == dense training forward."""
    t = toks(12)
    logits_d, _ = forward_dense(params, t, SMALL)
    logits_s = forward_serving_fp(params, t, SMALL)
    np.testing.assert_allclose(logits_s, logits_d, rtol=2e-4, atol=2e-4)


def test_attn_decode_matches_prefill_row(params):
    """Decoding token s against the prefill KV cache reproduces the
    prefill attention output at row s."""
    lp = params["layers"][0]
    s = 10
    x = jax.random.normal(jax.random.PRNGKey(0), (s, SMALL.d_model))
    h_pre, k, v = attn_prefill_step(x, jnp.int32(s), lp["ln1"], lp["wq"],
                                    lp["wk"], lp["wv"], lp["wo"], SMALL)
    # re-run last token through the decode path with cache holding rows < s-1
    h_dec, k2, v2 = attn_decode_step(
        x[s - 1 : s], k, v, jnp.int32(s - 1),
        lp["ln1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"], SMALL,
    )
    np.testing.assert_allclose(h_dec[0], h_pre[s - 1], rtol=1e-4, atol=1e-5)
    # cache row s-1 must be overwritten with identical values
    np.testing.assert_allclose(k2[:, s - 1], k[:, s - 1], rtol=1e-5, atol=1e-6)


def test_prefill_padding_does_not_change_valid_rows(params):
    lp = params["layers"][0]
    x = jax.random.normal(jax.random.PRNGKey(1), (16, SMALL.d_model))
    h_full, _, _ = attn_prefill_step(x, jnp.int32(16), lp["ln1"], lp["wq"],
                                     lp["wk"], lp["wv"], lp["wo"], SMALL)
    xp = jnp.concatenate([x[:9], jnp.zeros((7, SMALL.d_model))])
    h_pad, _, _ = attn_prefill_step(xp, jnp.int32(9), lp["ln1"], lp["wq"],
                                    lp["wk"], lp["wv"], lp["wo"], SMALL)
    h_ref, _, _ = attn_prefill_step(x[:9], jnp.int32(9), lp["ln1"], lp["wq"],
                                    lp["wk"], lp["wv"], lp["wo"], SMALL)
    np.testing.assert_allclose(h_pad[:9], h_ref, rtol=1e-4, atol=1e-5)


def test_embed_offset(params):
    t = toks(4)
    x0 = embed_step(t, jnp.int32(0), params["embed"], params["pos"])
    x5 = embed_step(t, jnp.int32(5), params["embed"], params["pos"])
    np.testing.assert_allclose(
        np.asarray(x5 - x0),
        np.asarray(params["pos"][5:9] - params["pos"][0:4]),
        rtol=1e-5, atol=1e-6,
    )


def test_gate_probs_normalized(params):
    lp = params["layers"][1]
    x = jax.random.normal(jax.random.PRNGKey(2), (6, SMALL.d_model))
    xn, p = gate_step(x, lp["ln2"], lp["wg"])
    assert p.shape == (6, SMALL.n_experts)
    np.testing.assert_allclose(np.asarray(p).sum(-1), 1.0, rtol=1e-5)


def test_loss_decreases_one_step(params):
    """Gradient sanity: one SGD step on a batch lowers its loss."""
    rng = np.random.default_rng(0)
    tokens = jnp.array(rng.integers(0, 256, (2, 33)), jnp.int32)
    (l0, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, tokens, SMALL)
    p2 = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
    l1, _ = loss_fn(p2, tokens, SMALL)
    assert float(l1) < float(l0)


def test_logits_shape(params):
    x = jax.random.normal(jax.random.PRNGKey(3), (7, SMALL.d_model))
    out = logits_step(x, params["ln_f"], params["w_out"], SMALL)
    assert out.shape == (7, SMALL.vocab)


def test_default_config_alignment():
    """Geometry constraints the kernels/AOT rely on."""
    assert CFG.d_model % CFG.group == 0
    assert CFG.d_ff % CFG.group == 0
    assert CFG.n_heads * CFG.d_head == CFG.d_model
    assert CFG.d_ff % 128 == 0  # DEFAULT_BLOCK_F
