"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

Hypothesis sweeps the kernel's shapes and bit configurations; every case
asserts allclose against the oracle. This is the CORE correctness signal
for the compute hot-spot.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quant
from compile.kernels import amat_ffn as K
from compile.kernels import ref as R

MATS = [(4, 2), (6, 3), (8, 4)]


def make_case(t, d, f, bh, bl, g, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((t, d)).astype(np.float32)
    ws = [
        (rng.standard_normal((d, f)) * 0.1).astype(np.float32),
        (rng.standard_normal((d, f)) * 0.1).astype(np.float32),
        (rng.standard_normal((f, d)) * 0.1).astype(np.float32),
    ]
    qs = [quant.quantize_asym(w, bh, g) for w in ws]
    planes = [quant.split_planes(q, bl) for q in qs]
    return x, ws, qs, planes


@settings(max_examples=12, deadline=None)
@given(
    t=st.sampled_from([1, 3, 8]),
    mat=st.sampled_from(MATS),
    seed=st.integers(0, 2**16),
)
def test_amat_ffn_high_matches_ref(t, mat, seed):
    bh, bl = mat
    d, f, g = 64, 128, 32
    x, ws, qs, planes = make_case(t, d, f, bh, bl, g, seed)
    shift = bh - bl
    args = []
    for (m, l), q in zip(planes, qs):
        args += [jnp.array(m), jnp.array(l), jnp.array(q.scale), jnp.array(q.zp)]
    y = K.amat_ffn_high(jnp.array(x), *args, group=g, shift=shift, block_f=64)
    y_ref = R.amat_ffn_high_ref(
        jnp.array(x),
        [(jnp.array(m), jnp.array(l)) for m, l in planes],
        [jnp.array(q.scale) for q in qs],
        [jnp.array(q.zp) for q in qs],
        g, shift,
    )
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    t=st.sampled_from([1, 5]),
    mat=st.sampled_from(MATS),
    seed=st.integers(0, 2**16),
)
def test_amat_ffn_low_matches_ref(t, mat, seed):
    bh, bl = mat
    d, f, g = 64, 128, 32
    x, ws, qs, planes = make_case(t, d, f, bh, bl, g, seed)
    lows = [quant.truncate_amat(q, bl) for q in qs]
    args = []
    for lo in lows:
        args += [jnp.array(lo.q), jnp.array(lo.scale), jnp.array(lo.zp)]
    y = K.amat_ffn_low(jnp.array(x), *args, group=g, block_f=64)
    y_ref = R.amat_ffn_low_ref(
        jnp.array(x),
        [jnp.array(l.q) for l in lows],
        [jnp.array(l.scale) for l in lows],
        [jnp.array(l.zp) for l in lows],
        g,
    )
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_high_kernel_equals_fp_on_dequantized_weights():
    """The quantized kernel is EXACTLY the fp kernel over dequantized w."""
    x, ws, qs, planes = make_case(4, 64, 128, 8, 4, 32, 0)
    args = []
    for (m, l), q in zip(planes, qs):
        args += [jnp.array(m), jnp.array(l), jnp.array(q.scale), jnp.array(q.zp)]
    y = K.amat_ffn_high(jnp.array(x), *args, group=32, shift=4, block_f=64)
    y_fp = K.ffn_fp(jnp.array(x), *[jnp.array(quant.dequantize_asym(q)) for q in qs],
                    block_f=64)
    np.testing.assert_allclose(y, y_fp, rtol=1e-5, atol=1e-5)


def test_low_kernel_supports_symmetric_codes():
    """Signed codes + zp=0 reproduce symmetric dequant (Table 1 Sym rows)."""
    rng = np.random.default_rng(3)
    d, f, g = 64, 128, 32
    x = rng.standard_normal((2, d)).astype(np.float32)
    ws = [(rng.standard_normal(s) * 0.1).astype(np.float32)
          for s in [(d, f), (d, f), (f, d)]]
    syms = [quant.quantize_sym(w, 4, g) for w in ws]
    args = []
    for s_ in syms:
        args += [jnp.array(s_.q), jnp.array(s_.scale), jnp.array(s_.zp)]
    y = K.amat_ffn_low(jnp.array(x), *args, group=g, block_f=64)
    y_ref = R.swiglu_ref(jnp.array(x), *[jnp.array(quant.dequantize_sym(s_)) for s_ in syms])
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([1, 4, 9]),
    block_f=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_ffn_fp_block_size_invariance(t, block_f, seed):
    """Output must not depend on the d_ff tile width (grid accumulation)."""
    rng = np.random.default_rng(seed)
    d, f = 32, 128
    x = rng.standard_normal((t, d)).astype(np.float32)
    w1, w3 = [(rng.standard_normal((d, f)) * 0.2).astype(np.float32) for _ in range(2)]
    w2 = (rng.standard_normal((f, d)) * 0.2).astype(np.float32)
    y = K.ffn_fp(jnp.array(x), jnp.array(w1), jnp.array(w3), jnp.array(w2),
                 block_f=block_f)
    y_ref = R.swiglu_ref(jnp.array(x), jnp.array(w1), jnp.array(w3), jnp.array(w2))
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([1, 6]),
    e=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_gate_softmax_matches_ref(t, e, seed):
    rng = np.random.default_rng(seed)
    d = 64
    x = rng.standard_normal((t, d)).astype(np.float32)
    g = rng.standard_normal(d).astype(np.float32)
    wg = rng.standard_normal((d, e)).astype(np.float32)
    xn, p = K.gate_softmax(jnp.array(x), jnp.array(g), jnp.array(wg))
    xn_ref = R.rmsnorm_ref(jnp.array(x), jnp.array(g))
    p_ref = R.gate_ref(xn_ref, jnp.array(wg))
    np.testing.assert_allclose(xn, xn_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(p, p_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(p).sum(-1), 1.0, rtol=1e-5)


def test_kernel_rejects_misaligned_block():
    x, ws, qs, planes = make_case(1, 64, 128, 8, 4, 32, 0)
    args = []
    for (m, l), q in zip(planes, qs):
        args += [jnp.array(m), jnp.array(l), jnp.array(q.scale), jnp.array(q.zp)]
    with pytest.raises(ValueError):
        K.amat_ffn_high(jnp.array(x), *args, group=32, shift=4, block_f=48)
