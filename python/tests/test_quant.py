"""AMAT quantization properties (hypothesis-driven).

These are the invariants DESIGN.md §Key-algorithms promises; the Rust
mirror (`rust/src/quant/`) is held to the same ones via golden files.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quant

BITS_PAIRS = [(4, 2), (6, 3), (8, 4)]


def rand_w(rows, cols, seed=0, scale=0.1, loc=0.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((rows, cols)) * scale + loc).astype(np.float32)


# ---------------------------------------------------------------------------
# Quantize / dequantize
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 6, 8]),
    group=st.sampled_from([16, 32, 64]),
    rows_g=st.integers(1, 4),
    cols=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
def test_asym_roundtrip_error_bound(bits, group, rows_g, cols, seed):
    """|w - dq(q(w))| <= scale/2 elementwise (asymmetric covers the range)."""
    w = rand_w(rows_g * group, cols, seed)
    p = quant.quantize_asym(w, bits, group)
    dq = quant.dequantize_asym(p)
    err = np.abs(dq - w)
    bound = np.repeat(p.scale, group, axis=0) * 0.5 + 1e-6
    assert (err <= bound).all()


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([3, 4, 6, 8]),
    seed=st.integers(0, 2**16),
)
def test_asym_codes_in_range(bits, seed):
    w = rand_w(64, 17, seed)
    p = quant.quantize_asym(w, bits, 32)
    assert p.q.min() >= 0 and p.q.max() <= 2**bits - 1
    assert p.zp.min() >= 0 and p.zp.max() <= 2**bits - 1


def test_degenerate_constant_group_is_exact():
    w = np.full((32, 5), 0.37, np.float32)
    p = quant.quantize_asym(w, 4, 32)
    assert np.allclose(quant.dequantize_asym(p), w, atol=1e-6)


def test_sym_zero_maps_to_zero():
    """Symmetric quantization must represent 0 exactly (zp-free)."""
    w = rand_w(64, 8, 3)
    w[5, :] = 0.0
    p = quant.quantize_sym(w, 4, 32)
    dq = quant.dequantize_sym(p)
    assert np.abs(dq[5]).max() == 0.0


# ---------------------------------------------------------------------------
# Matryoshka truncation (the paper's core equation)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,bl", BITS_PAIRS)
def test_msb_plane_equals_amat_truncation(bh, bl):
    """split_planes MSB == truncate_amat codes — MSB-only execution IS the
    AMAT low-bit quantizer (no duplicate copies, paper §4.2)."""
    w = rand_w(128, 33, 7)
    p = quant.quantize_asym(w, bh, 32)
    msb, lsb = quant.split_planes(p, bl)
    t = quant.truncate_amat(p, bl)
    assert (msb == t.q).all()
    assert np.allclose(t.scale, p.scale * 2 ** (bh - bl))
    assert (t.zp == (p.zp >> (bh - bl))).all()


@pytest.mark.parametrize("bh,bl", BITS_PAIRS)
def test_plane_merge_roundtrip(bh, bl):
    w = rand_w(96, 21, 11)
    p = quant.quantize_asym(w, bh, 32)
    msb, lsb = quant.split_planes(p, bl)
    assert (quant.merge_planes(msb, lsb, bh - bl) == p.q).all()
    assert msb.max() <= 2**bl - 1
    assert lsb.max() <= 2 ** (bh - bl) - 1


@pytest.mark.parametrize("bh,bl", BITS_PAIRS)
def test_amat_beats_naive_and_sym_truncation(bh, bl):
    """Table 1's ordering: AMAT error ~ fresh low-bit error, while naive
    asym truncation (stale zp) and symmetric truncation are far worse."""
    w = rand_w(512, 64, 5, scale=0.08, loc=0.02)  # asymmetric distribution
    p = quant.quantize_asym(w, bh, 32)

    def mse(dq):
        return float(((dq - w) ** 2).mean())

    amat = mse(quant.dequantize_asym(quant.truncate_amat(p, bl)))
    naive = mse(quant.dequantize_asym(quant.truncate_naive_asym(p, bl)))
    fresh = mse(quant.dequantize_asym(quant.quantize_asym(w, bl, 32)))
    sym = quant.quantize_sym(w, bh, 32)
    symt = mse(quant.dequantize_sym(quant.truncate_sym(sym, bl)))
    assert amat < naive, (amat, naive)
    assert amat < symt, (amat, symt)
    # AMAT stays within a small factor of an independently-quantized low-bit
    # tensor (Table 1: AMAT ~ Base at low bits).
    assert amat < 4.0 * fresh, (amat, fresh)
    # Naive truncation is catastrophically worse than AMAT.
    assert naive > 10.0 * amat


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_amat_truncation_is_floor_division(seed):
    """q_low == floor(q_high / 2^shift) exactly (paper's equation)."""
    w = rand_w(64, 9, seed)
    p = quant.quantize_asym(w, 8, 32)
    t = quant.truncate_amat(p, 4)
    assert (t.q == p.q // 16).all()
    assert (t.zp == p.zp // 16).all()


# ---------------------------------------------------------------------------
# Bit packing
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    bits=st.integers(1, 12),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**16),
)
def test_pack_unpack_roundtrip(bits, n, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2**bits, size=n)
    packed = quant.pack_bits(codes, bits)
    assert packed.size == (n * bits + 7) // 8
    assert (quant.unpack_bits(packed, bits, n) == codes).all()


def test_pack_rejects_out_of_range():
    with pytest.raises(ValueError):
        quant.pack_bits(np.array([4]), 2)


def test_nbytes_logical():
    w = rand_w(64, 32, 0)
    p = quant.quantize_asym(w, 4, 32)
    # 2048 codes * 4b = 1024B; 64 groups * (16b scale + 4b zp) = 160B
    assert p.nbytes_logical() == 1024 + 160
